//! Strongly-ordered replication path (§4.3–§4.4): Mu SMR instances per
//! *catalog-global* synchronization group — the data plane flattens each
//! object's local groups into one global index space (`Catalog::
//! global_group`), so a multi-object catalog gets one round pipeline and
//! one replication log per (object, group) pair — the replication logs,
//! leader-forwarding and requester bookkeeping, plus the Raft pipeline
//! (whose single total log tags entries with their `ObjectId` for
//! per-object apply), serving both the
//! Waverunner baseline (§5.2, which replicates *every* update through this
//! path with leader-only clients) and the stand-alone `backend = raft`
//! configuration (category-routed like Mu, leader-authoritative
//! permissibility, batched AppendEntries). The APUS-style Paxos backend
//! lives in its own plane, `engine::paxos`.
//!
//! The path owns its completion tokens ([`StrongToken`]): Mu round
//! responses and forwarded-op replies route back here via the coordinator's
//! token table. The former `TokenCtx::Raft` variant is gone — Raft
//! AppendEntries completions are logical (`Payload::RaftAck` verbs), so the
//! fan-out rides fire-and-forget `Ignore` tokens like all other
//! unacknowledged writes.

use crate::config::{ConsensusBackend, PropagationMode, SimConfig, SystemKind};
use crate::engine::path::{
    Membership, MembershipEvent, PendingClient, ReplicaCore, ReplicationPath, Requester,
    Submission, TokenCtx,
};
use crate::engine::store::{Catalog, KV_READ};
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{Payload, ReadData, ReadTarget, Verb};
use crate::rdt::OpCall;
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::smr::mu::{MuInstance, Resp, Round, Step};
use crate::smr::raft::{RaftFollower, RaftLeader, RaftStep};
use crate::util::hasher::FastMap;
use crate::workload::WorkItem;

/// Completion tokens owned by the strong path.
#[derive(Clone, Copy, Debug)]
pub enum StrongToken {
    /// Mu fan-out response: (group, round_id at fan-out time).
    Mu { group: u8, round_id: u64 },
    /// Forwarded conflicting op awaiting a LeaderReply.
    Forward { request_id: u64 },
}

pub struct StrongPath {
    prop_con: PropagationMode,
    /// Mu or Raft (Paxos lives in `engine::paxos`). Waverunner pins Raft.
    backend: ConsensusBackend,
    system: SystemKind,
    /// Leader-side log-entry batching bound (1 = off).
    batch: usize,
    /// Chaos mode (schedule has link faults): forwarded ops arm a reply
    /// watchdog and the Raft leader gets a periodic re-pump tick, since
    /// lossy links can eat the logical acks the pipeline waits on.
    chaos: bool,
    /// One Mu instance + replication log per synchronization group. Under
    /// `backend = raft` the group-0 log doubles as a mirror of the Raft
    /// log (proposal = term, kept fully applied) so snapshot transfer and
    /// anti-entropy replay work exactly like Mu/Paxos.
    mu: Vec<MuInstance>,
    logs: Vec<ReplicationLog>,
    round_id: Vec<u64>,
    requesters: FastMap<(usize, u64), Requester>,
    pending_fwd: FastMap<u64, PendingClient>,
    next_request_id: u64,
    /// Mu leadership confirmation: false from a promotion until the first
    /// WriteProposal round reaches quorum. A never-confirmed "leader" whose
    /// rounds stall while a smaller live node exists is a partition-side
    /// imposter and abdicates (it cannot have applied anything — Mu applies
    /// only at the Accept phase, which confirmation precedes).
    mu_confirmed: bool,
    /// Chaos-mode exactly-once ledger for forwarded ops: verdicts of
    /// already-ordered `(origin, seq)` pairs. A lost LeaderReply makes the
    /// origin's watchdog re-forward; without this the duplicate would
    /// execute twice in total order (converged but double-debited).
    done_fwd: FastMap<(usize, u64), bool>,
    // Raft fast path (Waverunner baseline + stand-alone backend).
    raft_leader: Option<RaftLeader>,
    raft_follower: RaftFollower,
    raft_pending: FastMap<u64, Requester>, // index -> requester
    /// Raft leadership lease: a promoted leader must collect a majority of
    /// append acks (its takeover replay / an empty probe) before serving —
    /// submissions park below until then, so a fenced partition-side
    /// imposter never applies or replicates anything and can abdicate
    /// cleanly. The boot leader holds the lease by construction.
    raft_lease: bool,
    raft_votes: FastMap<usize, ()>,
    raft_parked: Vec<(OpCall, Requester)>,
}

impl StrongPath {
    pub fn new(cfg: &SimConfig, id: NodeId, groups: usize) -> Self {
        // The Raft pipeline serves both Waverunner (whose preset pins
        // backend = Raft) and the stand-alone Raft backend; node 0 leads
        // fault-free runs either way.
        let raft_leader = if cfg.backend == ConsensusBackend::Raft
            && id == crate::smr::raft::initial_leader()
        {
            Some(RaftLeader::with_batch(cfg.n_replicas, cfg.batch_size as usize))
        } else {
            None
        };
        StrongPath {
            prop_con: cfg.prop_conflicting,
            backend: cfg.backend,
            system: cfg.system,
            batch: cfg.batch_size as usize,
            chaos: cfg.fault.has_link_faults(),
            mu: (0..groups).map(|g| MuInstance::new(g as u8, cfg.n_replicas)).collect(),
            logs: (0..groups).map(|_| ReplicationLog::new()).collect(),
            round_id: vec![0; groups],
            requesters: FastMap::default(),
            pending_fwd: FastMap::default(),
            next_request_id: 1,
            mu_confirmed: true,
            done_fwd: FastMap::default(),
            raft_leader,
            raft_follower: RaftFollower::new(),
            raft_pending: FastMap::default(),
            raft_lease: true,
            raft_votes: FastMap::default(),
            raft_parked: Vec::new(),
        }
    }

    /// Mirror a run of Raft entries into the group-0 replication log so the
    /// generic snapshot/replay machinery sees the Raft log. The mirror is
    /// kept fully applied — Raft applies through its own automaton — so the
    /// Mu-style quiescence drain never double-executes.
    fn raft_mirror_append(&mut self, start: u64, term: u64, ops: &[OpCall]) {
        if self.logs.is_empty() {
            self.logs.push(ReplicationLog::new());
        }
        let log = &mut self.logs[0];
        for (i, op) in ops.iter().enumerate() {
            log.write_slot(start + i as u64, term, *op);
        }
        log.applied_upto = log.applied_upto.max(log.next_free_slot());
    }

    fn drain_logs_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        for g in 0..self.logs.len() {
            for entry in self.logs[g].drain_unapplied() {
                cost += core.exec().op_exec_ns + core.sys.mem.local_read_ns(core.landing_mem());
                core.executions += 1;
                core.plane.apply_forced(&entry.op);
            }
        }
        cost
    }

    fn submit_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if core.system == SystemKind::Waverunner {
            self.waverunner_submit(core, ctx, mb, op, req);
            return;
        }
        if self.backend == ConsensusBackend::Raft {
            self.raft_submit(core, ctx, mb, op, req);
            return;
        }
        self.requesters.insert((op.origin, op.seq), req);
        if core.is_leader() {
            // Catalog flattening: (object, local sync group) -> global
            // group, one Mu round pipeline + replication log per global
            // group.
            let g = core.plane.global_group(&op) as usize;
            let slot = self.logs[g].next_free_slot();
            if let Some(round) = self.mu[g].submit(op, slot) {
                self.fan_out_round(core, ctx, mb, g, round);
            }
        } else {
            self.forward_conflicting(core, ctx, op, req);
        }
    }

    /// Forward a conflicting op to the leader (one RPC-sized write; §4.3).
    fn forward_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, op: OpCall, req: Requester) {
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        if let Requester::Local { client, arrival } = req {
            self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op });
            if self.chaos {
                core.arm_forward_watchdog(ctx, request_id);
            }
        }
        let leader = core.leader;
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let start = ctx.q.now().max(core.busy_until);
        let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, start, core.id, leader, verb, true);
        core.busy_total += out.initiator_free_at - start;
        core.busy_until = out.initiator_free_at;
    }

    // ----- stand-alone Raft backend (non-Waverunner) ---------------------

    /// Promote this replica to Raft leader if it isn't one yet (election
    /// takeover, or an origin-side retry that self-elected first). The
    /// promotion opens a lease campaign: the adopted log is re-replicated
    /// at the bumped term (an empty probe when there is nothing to
    /// replay), and follower acks become the lease votes.
    fn ensure_raft_leader(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership) {
        if self.raft_leader.is_some() {
            return;
        }
        let term = self.raft_follower.term + 1;
        let next = self.raft_follower.log_len();
        self.raft_leader = Some(RaftLeader::promote(mb.live_set().len(), self.batch, term, next));
        self.raft_lease = false;
        self.raft_votes = FastMap::default();
        self.raft_campaign(core, ctx, mb);
        if !self.raft_lease {
            // Campaign-retry chain: probes may be fenced at followers that
            // have not run their permission switch yet.
            ctx.q.push(
                ctx.q.now() + core.heartbeat_period_ns,
                core.id,
                EventKind::Timer(TimerKind::SmrTick(0)),
            );
        }
    }

    /// One lease-campaign wave: term-bumped replay of the adopted log to
    /// every live peer (followers overwrite-accept, which is idempotent),
    /// or an empty probe batch when the log is empty. Solo leaders grant
    /// themselves the lease — there is no one left to vote.
    fn raft_campaign(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership) {
        if mb.live_set().len() / 2 == 0 {
            self.raft_grant_lease(core, ctx, mb);
            return;
        }
        let entries: Vec<OpCall> = self.raft_follower.entries().to_vec();
        let term = self.raft_leader.as_ref().expect("campaigning leader").term;
        let peers = mb.live_peers(core.id);
        if entries.is_empty() {
            for peer in peers {
                self.raft_send_to(core, ctx, peer, term, 0, Vec::new());
            }
            return;
        }
        let step = self.batch.max(1);
        let mut start = 0usize;
        while start < entries.len() {
            let end = (start + step).min(entries.len());
            self.raft_fan_out(core, ctx, mb, term, start as u64, entries[start..end].to_vec());
            start = end;
        }
    }

    /// A follower acknowledged our current term: count it toward the
    /// lease. Majority (of the live view) grants it and drains the parked
    /// submissions through the normal leader entry.
    fn raft_lease_vote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, term: u64, from: NodeId) {
        if self.raft_lease {
            return;
        }
        let Some(rl) = self.raft_leader.as_ref() else { return };
        if rl.term != term {
            return;
        }
        self.raft_votes.insert(from, ());
        if self.raft_votes.len() >= mb.live_set().len() / 2 {
            self.raft_grant_lease(core, ctx, mb);
        }
    }

    fn raft_grant_lease(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership) {
        self.raft_lease = true;
        let parked = std::mem::take(&mut self.raft_parked);
        for (op, req) in parked {
            self.raft_submit(core, ctx, mb, op, req);
        }
    }

    /// A promoted-but-unleased "leader" learned a smaller live node exists
    /// (typically after a partition heals): it was a minority imposter.
    /// Nothing was applied or replicated while parked, so abdication is a
    /// pure re-route: adopt the rightful view, re-fence the QP row, and
    /// push the parked ops back through the forward path.
    fn raft_abdicate(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, rightful: NodeId) {
        ctx.qps.switch_leader(core.id, core.leader, rightful);
        core.leader = rightful;
        self.raft_leader = None;
        self.raft_lease = true;
        self.raft_votes = FastMap::default();
        core.request_sync(ctx, rightful);
        let parked = std::mem::take(&mut self.raft_parked);
        for (op, req) in parked {
            match req {
                Requester::Local { .. } => self.forward_conflicting(core, ctx, op, req),
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false)
                }
            }
        }
    }

    /// Generic Raft leader entry: unlike Waverunner's (which replicates
    /// even locally-rejected applies to mirror §5.2), the stand-alone
    /// backend gives the leader Mu-equivalent authority — an op that fails
    /// permissibility in total-order position is rejected, not replicated;
    /// followers then apply the log unconditionally (`apply_forced`).
    fn raft_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if !core.is_leader() {
            self.forward_conflicting(core, ctx, op, req);
            return;
        }
        self.ensure_raft_leader(core, ctx, mb);
        if !self.raft_lease {
            // Leadership not confirmed by a follower majority yet: park.
            self.raft_parked.push((op, req));
            return;
        }
        if !core.plane.permissible(&op) {
            core.note_rejected(&op);
            if self.chaos {
                self.done_fwd.insert((op.origin, op.seq), false);
            }
            self.answer_requester(core, ctx, req, false);
            return;
        }
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft_leader.as_mut().expect("just ensured");
        let term = rl.term;
        let (index, fanout) = rl.submit(op);
        self.raft_mirror_append(index, term, &[op]);
        self.raft_pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.raft_fan_out(core, ctx, mb, term, start, ops);
        }
    }

    fn fan_out_round(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, round: Round) {
        self.round_id[g] += 1;
        let rid = self.round_id[g];
        let group = g as u8;
        let peers = mb.live_peers(core.id);
        self.mu[g].round_started(peers.len() as u32);
        let use_wt = self.prop_con == PropagationMode::WriteThrough;
        // Sequential SMR: the leader is execution-busy from the previous
        // round's fan-out through this round's quorum (appendix D.1).
        let now = ctx.q.now();
        if now > core.busy_until {
            core.busy_total += now - core.busy_until;
            core.busy_until = now;
        }
        let start = ctx.q.now().max(core.busy_until);
        let mut cursor = start;
        for dst in peers {
            let tok = core.token(TokenCtx::Strong(StrongToken::Mu { group, round_id: rid }));
            // All rounds want completions: writes for quorum ACKs, reads so
            // crashed followers surface as NACKs (reads otherwise complete
            // via ReadResp).
            let verb = match round {
                Round::ReadMinProposals => Verb::read(ReadTarget::MinProposal { group }, tok),
                Round::WriteProposal { proposal } => {
                    Verb::write(core.landing_mem_for_peer(), Payload::Propose { group, proposal }, tok)
                        .on_leader_qp()
                }
                Round::ReadSlots { slot } => Verb::read(ReadTarget::LogSlot { group, slot }, tok),
                Round::WriteLog { slot, proposal, op, adopted: _ } => {
                    let payload = Payload::LogAppend { group, slot, proposal, op };
                    if use_wt {
                        Verb::rpc_write_through(payload, tok)
                    } else {
                        Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                    }
                }
            };
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, cursor, core.id, dst, verb, true);
            cursor = out.initiator_free_at;
        }
        core.busy_total += cursor - start;
        core.busy_until = cursor;
    }

    fn mu_step(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, step: Step) {
        match step {
            Step::Wait => {}
            Step::Next(round) => {
                // A WriteProposal quorum (the transition into ReadSlots)
                // means a follower majority accepted this leadership —
                // confirmation, in lease terms.
                if matches!(round, Round::ReadSlots { .. }) {
                    self.mu_confirmed = true;
                }
                if let Round::WriteLog { slot, proposal, op, adopted } = round {
                    // Accept phase entry: the leader *executes* the
                    // transaction before writing followers' logs (§4.4).
                    // Its permissibility check here is authoritative — the
                    // op sits at a fixed position in the total order.
                    if !adopted && !core.plane.permissible(&op) {
                        core.note_rejected(&op);
                        self.mu[g].abort_current();
                        if self.chaos {
                            self.done_fwd.insert((op.origin, op.seq), false);
                        }
                        if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                            self.answer_requester(core, ctx, req, false);
                        }
                        let next = self.logs[g].next_free_slot();
                        if let Some(round) = self.mu[g].pump(next) {
                            self.fan_out_round(core, ctx, mb, g, round);
                        }
                        return;
                    }
                    // Execute locally unless this replica already applied
                    // the entry (e.g. it drained it from its log as a
                    // follower before winning the election).
                    if self.logs[g].applied_upto <= slot {
                        let exec_cost = core.exec().op_exec_ns + core.write_state_cost(false);
                        core.occupy(ctx.q.now(), exec_cost);
                        if adopted {
                            core.plane.apply_forced(&op);
                        } else {
                            core.plane.apply(&op);
                        }
                        core.executions += 1;
                    }
                    self.logs[g].write_slot(slot, proposal, op);
                    self.logs[g].applied_upto = self.logs[g].applied_upto.max(slot + 1);
                }
                self.fan_out_round(core, ctx, mb, g, round)
            }
            Step::Commit { slot: _, proposal: _, op, adopted: _ } => {
                // Quorum of followers acked the Accept write: committed.
                // The SMR pipeline is sequential per group — the leader is
                // execution-time-busy through the whole round (appendix
                // D.1: the leader is the longest-running replica).
                let now = ctx.q.now();
                if now > core.busy_until {
                    core.busy_total += now - core.busy_until;
                    core.busy_until = now;
                }
                ctx.metrics.smr_commits += 1;
                if self.chaos {
                    self.done_fwd.insert((op.origin, op.seq), true);
                }
                if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                    self.answer_requester(core, ctx, req, true);
                }
                // Pump the next queued conflicting op.
                let slot = self.logs[g].next_free_slot();
                if let Some(round) = self.mu[g].pump(slot) {
                    self.fan_out_round(core, ctx, mb, g, round);
                }
            }
            Step::Stall => {
                // A stalled round on a never-confirmed leadership, while a
                // smaller live node exists, means this replica self-elected
                // inside a partition minority and every correct replica
                // fences its writes: abdicate. Nothing was applied (Mu
                // executes only at Accept, past confirmation), so the
                // queued ops simply re-route through the forward path.
                if !self.mu_confirmed {
                    let rightful = mb.elect_leader();
                    if rightful != core.id {
                        self.mu_abdicate(core, ctx, rightful);
                        return;
                    }
                }
                self.mu[g].reset_in_flight();
                // Retry once the heartbeat scanner refreshes the live set.
                ctx.q.push(
                    ctx.q.now() + core.heartbeat_period_ns,
                    core.id,
                    EventKind::Timer(TimerKind::SmrTick(g as u8)),
                );
            }
        }
    }

    /// Mu abdication (see `Step::Stall`): adopt the rightful leader view,
    /// re-fence our own QP row, and hand every queued conflicting op back
    /// to the forward path (remote requesters bounce so origins retry).
    fn mu_abdicate(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, rightful: NodeId) {
        ctx.qps.switch_leader(core.id, core.leader, rightful);
        core.leader = rightful;
        self.mu_confirmed = true; // provisional reign over; next promotion resets
        core.request_sync(ctx, rightful);
        for g in 0..self.mu.len() {
            self.mu[g].reset_in_flight();
            for op in self.mu[g].take_queue() {
                match self.requesters.remove(&(op.origin, op.seq)) {
                    Some(req @ Requester::Local { .. }) => self.forward_conflicting(core, ctx, op, req),
                    Some(Requester::Remote { reply_to, request_id }) => {
                        self.reply_remote(core, ctx, reply_to, request_id, false, false)
                    }
                    None => {}
                }
            }
        }
    }

    fn answer_requester(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, req: Requester, committed: bool) {
        match req {
            Requester::Local { client, arrival } => {
                let t = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                core.complete_client(ctx, client, arrival, t);
            }
            Requester::Remote { reply_to, request_id } => {
                self.reply_remote(core, ctx, reply_to, request_id, true, committed);
            }
        }
    }

    fn reply_remote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, reply_to: NodeId, request_id: u64, handled: bool, committed: bool) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderReply { request_id, handled, committed },
            tok,
        );
        ctx.metrics.verbs += 1;
        let now = ctx.q.now().max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, reply_to, verb, false);
    }

    fn retry_forward(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, mut p: PendingClient) {
        p.retries += 1;
        if p.retries > 8 {
            // Give up: count as rejected so the run terminates.
            core.note_rejected(&p.op);
            let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, p.client, p.arrival, done);
            return;
        }
        // Re-forward to the current leader view after a beat.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, p);
        let leader = mb.elect_leader();
        core.leader = leader;
        let op = p.op;
        if leader == core.id {
            let pc = self.pending_fwd.remove(&request_id).unwrap();
            self.submit_conflicting(core, ctx, mb, op, Requester::Local { client: pc.client, arrival: pc.arrival });
            return;
        }
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        if self.chaos {
            core.arm_forward_watchdog(ctx, request_id);
        }
        let at = ctx.q.now() + core.heartbeat_period_ns;
        let at = at.max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, at, core.id, leader, verb, true);
    }

    /// Recovery: re-issue committed entries to a returned follower (§3).
    fn replay_log_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, peer: NodeId) {
        for g in 0..self.logs.len() {
            let entries = self.logs[g].entries_from(0);
            for (slot, e) in entries {
                let tok = core.token(TokenCtx::Ignore);
                let payload = Payload::LogAppend { group: g as u8, slot, proposal: e.proposal, op: e.op };
                let verb = if self.prop_con == PropagationMode::WriteThrough {
                    Verb::rpc_write_through(payload, tok)
                } else {
                    Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                };
                ctx.metrics.verbs += 1;
                ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
            }
        }
    }

    /// One AppendEntries (single or batched) to a single peer — the
    /// directed half of `raft_fan_out`, used by recovery replay, the
    /// RaftRejected backfill, and (with an empty batch) the lease probe.
    fn raft_send_to(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        peer: NodeId,
        term: u64,
        start: u64,
        ops: Vec<OpCall>,
    ) {
        let mem = if core.system == SystemKind::Waverunner {
            MemKind::HostDram
        } else {
            core.landing_mem_for_peer()
        };
        let tok = core.token(TokenCtx::Ignore);
        let payload = if ops.len() == 1 {
            Payload::RaftAppend { term, index: start, op: ops[0] }
        } else {
            Payload::RaftAppendBatch { term, start_index: start, ops: ops.into() }
        };
        ctx.metrics.verbs += 1;
        let verb = Verb::write(mem, payload, tok).on_leader_qp();
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
    }

    /// Recovery / anti-entropy: re-ship the mirrored Raft log to one peer
    /// from `from_index`, chunked like any other append. Followers
    /// overwrite-accept (idempotent) and ack each chunk's last index, so a
    /// chunk that completes the in-flight batch still counts toward its
    /// quorum.
    fn raft_replay_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, peer: NodeId, from_index: u64) {
        let entries = match self.logs.first() {
            Some(l) => l.entries_from(from_index),
            None => return,
        };
        if entries.is_empty() {
            return;
        }
        let term = self.raft_leader.as_ref().map(|l| l.term).unwrap_or(self.raft_follower.term);
        let first = entries[0].0;
        let ops: Vec<OpCall> = entries.into_iter().map(|(_, e)| e.op).collect();
        let step = self.batch.max(1);
        let mut start = 0usize;
        while start < ops.len() {
            let end = (start + step).min(ops.len());
            self.raft_send_to(core, ctx, peer, term, first + start as u64, ops[start..end].to_vec());
            start = end;
        }
    }

    /// Follower side of a gap: tell the leader where our log ends so it
    /// backfills (classic Raft nextIndex back-up, collapsed to one step —
    /// gaps only open when fault injection eats an append).
    fn raft_reject(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, leader: NodeId, term: u64) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::RaftRejected { term, from: core.id, log_len: self.raft_follower.log_len() },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, leader, verb, false);
    }

    // ----- waverunner (Raft baseline, §5.2) ------------------------------

    fn waverunner_redirect(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        // Follower rejects; client re-sends to the leader (§5.2). Modeled
        // as a forward carrying the client's retry round trip.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op: item.op });
        if self.chaos {
            core.arm_forward_watchdog(ctx, request_id);
        }
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op: item.op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        // Reject + client re-send penalty before the forward goes out.
        let penalty = core.exec().client_overhead_ns + core.sys.fabric.wire_ns * 2;
        let now = core.occupy(arrival, penalty);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, 0, verb, true);
    }

    /// Raft-leader client service: reads are local; every update goes
    /// through the replication pipeline.
    fn waverunner_serve(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, client: usize, item: WorkItem, arrival: Time) {
        let ingress = core.exec().client_overhead_ns / 2;
        let sw = core.exec().software_overhead_ns;
        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            let cost = ingress + sw + core.warm_read_ns() + core.exec().client_overhead_ns / 2;
            let done = core.occupy(arrival, cost);
            core.complete_client(ctx, client, arrival, done);
            return;
        }
        core.occupy(arrival, ingress + sw);
        self.waverunner_submit(core, ctx, mb, op, Requester::Local { client, arrival });
    }

    fn waverunner_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if self.raft_leader.is_none() {
            // Not the Raft leader, and Waverunner models no leader election
            // (§5.2 runs fault-free; smallest-live-ID is a documented
            // shortcut that never re-homes the RaftLeader). Every stranded
            // request must still terminate — the cluster's drain flag now
            // tracks in-flight slots for real: forwarded requests bounce so
            // the origin retries (and gives up after 8 beats), local ones
            // complete as rejected.
            match req {
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
                Requester::Local { client, arrival } => {
                    core.note_rejected(&op);
                    let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                    core.complete_client(ctx, client, arrival, done);
                }
            }
            return;
        }
        // The leader applies every update (its own and forwarded ones) at
        // submit; followers apply from the replicated log.
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft_leader.as_mut().unwrap();
        let term = rl.term;
        let (index, fanout) = rl.submit(op);
        self.raft_mirror_append(index, term, &[op]);
        self.raft_pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.raft_fan_out(core, ctx, mb, term, start, ops);
        }
    }

    /// Follower-side apply after an accepted AppendEntries. Waverunner
    /// replays the leader's raw op stream (its leader replicates even
    /// locally-rejected applies, so followers re-run the same `apply`
    /// decisions); the stand-alone backend ships only leader-accepted ops,
    /// which followers execute unconditionally like Mu's log drain.
    fn raft_follower_apply(&mut self, core: &mut ReplicaCore) {
        let forced = core.system != SystemKind::Waverunner;
        for o in self.raft_follower.drain_apply() {
            if forced {
                core.executions += 1;
                core.plane.apply_forced(&o);
            } else {
                core.apply_remote(&o);
            }
        }
    }

    fn raft_ack(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, src: NodeId, term: u64, index: u64) {
        let tok = core.token(TokenCtx::Ignore);
        let ack = Verb::write(
            core.landing_mem_for_peer(),
            Payload::RaftAck { term, index, from: core.id },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, src, ack, false);
    }

    fn raft_fan_out(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, term: u64, start: u64, ops: Vec<OpCall>) {
        // The logical ack is the RaftAck verb, not a wire completion.
        let peers = mb.live_peers(core.id);
        let mem = if core.system == SystemKind::Waverunner {
            MemKind::HostDram // SmartNIC fast path still lands in host state
        } else {
            core.landing_mem_for_peer()
        };
        if ops.len() == 1 {
            let op = ops[0];
            core.fan_out(
                ctx,
                &peers,
                |t| Verb::write(mem, Payload::RaftAppend { term, index: start, op }, t).on_leader_qp(),
                false,
                || TokenCtx::Ignore,
            );
        } else {
            // Leader-side log-entry batching: one AppendEntries wire verb
            // carries the whole contiguous run; the shared `Arc` batch
            // makes each per-peer clone a refcount bump (§Perf).
            ctx.metrics.coalesced += ops.len() as u64 - 1;
            let ops: crate::net::verbs::OpBatch = ops.into();
            core.fan_out(
                ctx,
                &peers,
                |t| {
                    Verb::write(
                        mem,
                        Payload::RaftAppendBatch { term, start_index: start, ops: ops.clone() },
                        t,
                    )
                    .on_leader_qp()
                },
                false,
                || TokenCtx::Ignore,
            );
        }
    }
}

impl ReplicationPath for StrongPath {
    fn boot(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64) {
        // Log pollers are a Mu follower concern; Raft followers apply at
        // delivery (the SmartNIC interrupt path), so they arm nothing.
        if self.backend == ConsensusBackend::Mu
            && self.prop_con != PropagationMode::WriteThrough
            && !self.logs.is_empty()
        {
            for g in 0..self.logs.len() {
                ctx.q.push(
                    base + core.poll_interval_ns + g as u64,
                    core.id,
                    EventKind::Timer(TimerKind::PollLog(g as u8)),
                );
            }
        }
        // Chaos mode: the Raft pipeline's logical acks can be eaten by
        // lossy links, so every replica arms the re-pump tick (it only
        // acts while this replica leads).
        if self.chaos && self.backend == ConsensusBackend::Raft {
            ctx.q.push(
                base + core.heartbeat_period_ns,
                core.id,
                EventKind::Timer(TimerKind::SmrTick(0)),
            );
        }
    }

    fn refresh_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        // Conflicting log check (§4.3 config 1: "polling the log when the
        // state is accessed to ensure the most up to date data") — a Mu
        // structure; Raft followers are already current at delivery.
        if self.backend == ConsensusBackend::Mu && self.prop_con != PropagationMode::WriteThrough {
            let per_group = core.sys.mem.local_read_ns(core.landing_mem());
            cost += per_group * self.logs.len() as u64;
            cost += self.drain_logs_cost(core);
        }
        cost
    }

    fn handle_client(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        mb: &dyn Membership,
        client: usize,
        item: WorkItem,
        arrival: Time,
    ) -> bool {
        // Waverunner: only the leader serves clients (§5.2); every update
        // replicates through Raft regardless of RDT category (no hybrid
        // consistency — that is the point of the Fig 12 comparison).
        if core.system != SystemKind::Waverunner {
            return false;
        }
        if self.raft_leader.is_none() {
            self.waverunner_redirect(core, ctx, client, item, arrival);
        } else {
            self.waverunner_serve(core, ctx, mb, client, item, arrival);
        }
        true
    }

    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission) {
        let _t = core.occupy(sub.arrival, sub.cost);
        self.submit_conflicting(core, ctx, mb, sub.op, Requester::Local { client: sub.client, arrival: sub.arrival });
    }

    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, src: NodeId, verb: Verb) {
        let is_rpc = matches!(verb.kind, crate::net::verbs::VerbKind::Rpc | crate::net::verbs::VerbKind::RpcWriteThrough);
        match verb.payload {
            Payload::Propose { group, proposal } => {
                self.logs[group as usize].bump_min_proposal(proposal);
            }
            Payload::LogAppend { group, slot, proposal, op } => {
                let g = group as usize;
                // A slot beyond our append point means an earlier Accept
                // write never landed here (fenced pre-switch, or eaten by
                // fault injection): ask the sender for a replay. Never
                // fires on a clean in-order fabric.
                if slot > self.logs[g].next_free_slot() {
                    core.request_sync(ctx, src);
                }
                self.logs[g].write_slot(slot, proposal, op);
                if is_rpc {
                    // Write-through: follower state updated directly from
                    // the network (§4.4 "at L"); log is already appended.
                    let cost = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy(ctx.q.now(), cost);
                    for e in self.logs[g].drain_unapplied() {
                        core.executions += 1;
                        core.plane.apply_forced(&e.op);
                    }
                }
            }
            Payload::LeaderForward { op, reply_to, request_id } => {
                if core.system == SystemKind::Waverunner {
                    // Redirected client request reaching the Raft leader.
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    if op.is_query() || op.opcode == KV_READ {
                        let cost = core.warm_read_ns() + core.exec().client_overhead_ns / 2;
                        core.occupy(ctx.q.now(), cost);
                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                    } else {
                        self.waverunner_submit(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                    }
                } else if core.is_leader() {
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    // Chaos-mode exactly-once: a duplicate of an op we
                    // already ordered (its reply was eaten by a faulty
                    // link) answers with the recorded verdict instead of
                    // executing twice.
                    if self.chaos {
                        if let Some(&committed) = self.done_fwd.get(&(op.origin, op.seq)) {
                            self.reply_remote(core, ctx, reply_to, request_id, true, committed);
                            return;
                        }
                    }
                    // Leader re-checks permissibility in total order context.
                    self.submit_conflicting(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                } else {
                    // Not the leader (stale forward): bounce.
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
            }
            Payload::LeaderReply { request_id, handled, committed } => {
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    if handled {
                        if !committed {
                            core.note_rejected(&p.op);
                        }
                        let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                        core.complete_client(ctx, p.client, p.arrival, done);
                    } else {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
            Payload::RaftAppend { term, index, op } => {
                if self.raft_follower.on_append(term, index, op) {
                    self.raft_mirror_append(index, term, &[op]);
                    self.raft_follower_apply(core);
                    self.raft_ack(core, ctx, src, term, index);
                } else if term >= self.raft_follower.term && index > self.raft_follower.log_len() {
                    self.raft_reject(core, ctx, src, term);
                }
            }
            Payload::RaftAppendBatch { term, start_index, ops } => {
                if self.raft_follower.on_append_batch(term, start_index, &ops) {
                    self.raft_mirror_append(start_index, term, &ops);
                    self.raft_follower_apply(core);
                    // One ack for the whole batch, on its last index (an
                    // empty batch is a lease probe — ack its start).
                    let last = start_index + (ops.len() as u64).max(1) - 1;
                    self.raft_ack(core, ctx, src, term, last);
                } else if term >= self.raft_follower.term
                    && start_index > self.raft_follower.log_len()
                {
                    self.raft_reject(core, ctx, src, term);
                }
            }
            Payload::RaftRejected { term, from, log_len } => {
                // A follower told us where its log ends (fault injection
                // ate an append): backfill from the mirrored log. The gap
                // report also proves it accepted our term — a lease vote.
                self.raft_lease_vote(core, ctx, mb, term, from);
                let current = self.raft_leader.as_ref().is_some_and(|rl| rl.term == term);
                if current {
                    self.raft_replay_to(core, ctx, from, log_len);
                }
            }
            Payload::SyncRequest { from } => {
                // A follower completed its permission switch toward us and
                // wants the committed log (our takeover broadcast may have
                // been fenced at it). Idempotent on both backends.
                if core.is_leader() {
                    if self.backend == ConsensusBackend::Raft {
                        self.raft_replay_to(core, ctx, from, 0);
                    } else {
                        self.replay_log_to(core, ctx, from);
                    }
                }
            }
            Payload::RaftAck { term, index, from } => {
                // A current-term ack is also a lease vote for a freshly
                // promoted leader (the follower accepted our authority).
                self.raft_lease_vote(core, ctx, mb, term, from);
                if let Some(rl) = self.raft_leader.as_mut() {
                    if let RaftStep::Commit { start_index, ops } = rl.on_ack(term, index, from) {
                        // Leader state was updated at submit; commit point
                        // is the quorum ack.
                        let done = core.occupy(ctx.q.now(), core.exec().op_exec_ns);
                        ctx.metrics.smr_commits += ops.len() as u64;
                        if self.chaos {
                            for o in &ops {
                                self.done_fwd.insert((o.origin, o.seq), true);
                            }
                        }
                        for i in 0..ops.len() as u64 {
                            if let Some(req) = self.raft_pending.remove(&(start_index + i)) {
                                match req {
                                    Requester::Local { client, arrival } => {
                                        let t = core.occupy(done, core.exec().client_overhead_ns / 2);
                                        core.complete_client(ctx, client, arrival, t);
                                    }
                                    Requester::Remote { reply_to, request_id } => {
                                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                                    }
                                }
                            }
                        }
                        if let Some((term, start, ops)) = self.raft_leader.as_mut().unwrap().pump() {
                            self.raft_fan_out(core, ctx, mb, term, start, ops);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn on_completion(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, ok: bool) {
        let TokenCtx::Strong(token) = token else { return };
        match token {
            StrongToken::Mu { group, round_id } => {
                let g = group as usize;
                if round_id != self.round_id[g] {
                    return; // stale round
                }
                let step = self.mu[g].on_response(if ok { Resp::Ack } else { Resp::Failure });
                self.mu_step(core, ctx, mb, g, step);
            }
            StrongToken::Forward { request_id } => {
                if !ok {
                    if let Some(p) = self.pending_fwd.remove(&request_id) {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
        }
    }

    fn on_read_resp(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, data: ReadData) {
        // Only Mu rounds read remote state; Forward tokens ride writes.
        let TokenCtx::Strong(StrongToken::Mu { group, round_id }) = token else { return };
        let g = group as usize;
        if round_id != self.round_id[g] {
            return; // stale round
        }
        let resp = match data {
            ReadData::MinProposal(p) => Resp::MinProposal(p),
            ReadData::LogSlot(s) => Resp::Slot(s),
            _ => Resp::Ack,
        };
        let step = self.mu[g].on_response(resp);
        self.mu_step(core, ctx, mb, g, step);
    }

    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind) {
        match t {
            TimerKind::PollLog(_g) => {
                let cost = core.exec().poll_tick_ns + self.drain_logs_cost(core);
                core.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::SmrTick(g) => {
                if self.backend == ConsensusBackend::Raft {
                    // Chaos-mode re-pump: a dropped append or eaten logical
                    // ack can wedge the one-in-flight pipeline, so the
                    // leader periodically re-ships the in-flight batch.
                    // Followers overwrite-accept duplicates and re-ack.
                    // An unleased leader instead re-runs its campaign — or
                    // abdicates once a smaller live node is back in view
                    // (the partition healed and it was a minority imposter).
                    if core.is_leader() {
                        if !self.raft_lease && self.raft_leader.is_some() {
                            let rightful = mb.elect_leader();
                            if rightful != core.id {
                                self.raft_abdicate(core, ctx, rightful);
                            } else {
                                self.raft_campaign(core, ctx, mb);
                            }
                        } else if let Some(rl) = self.raft_leader.as_mut() {
                            rl.set_cluster_size(mb.live_set().len());
                            if let Some((term, start, ops)) = rl.refanout() {
                                self.raft_fan_out(core, ctx, mb, term, start, ops);
                            }
                        }
                    }
                    // Re-arm: permanently in chaos mode, and as a one-shot
                    // chain while a lease campaign is still out (probes can
                    // be fenced at followers that have not switched yet).
                    let campaigning = !self.raft_lease && self.raft_leader.is_some();
                    if (self.chaos || campaigning) && !ctx.draining {
                        ctx.q.push(
                            ctx.q.now() + core.heartbeat_period_ns,
                            core.id,
                            EventKind::Timer(t),
                        );
                    }
                    return;
                }
                let g = g as usize;
                if core.is_leader() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                    let slot = self.logs[g].next_free_slot();
                    if let Some(round) = self.mu[g].pump(slot) {
                        self.fan_out_round(core, ctx, mb, g, round);
                    }
                }
            }
            TimerKind::ForwardCheck { request_id } => {
                // Chaos-mode watchdog: the leader's reply never arrived
                // (lost on a faulty link) — re-forward. At-least-once is
                // safe: the leader re-checks permissibility in total-order
                // position, and retry_forward gives up after its cap.
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
            _ => {}
        }
    }

    fn serve_read(&self, target: ReadTarget) -> Option<ReadData> {
        match target {
            ReadTarget::MinProposal { group } => {
                Some(ReadData::MinProposal(self.logs[group as usize].min_proposal))
            }
            ReadTarget::LogSlot { group, slot } => Some(ReadData::LogSlot(
                self.logs[group as usize].read_slot(slot).map(|e| (e.proposal, e.op)),
            )),
            _ => None,
        }
    }

    fn on_membership(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, ev: MembershipEvent) {
        match ev {
            MembershipEvent::PeerFailed { peer: _ } => {
                // Leader trims its follower list (background on SafarDB,
                // foreground cost charged by the failure plane for Hamband).
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                if let Some(rl) = self.raft_leader.as_mut() {
                    rl.set_cluster_size(mb.live_set().len());
                }
            }
            MembershipEvent::PeerRecovered { peer } => {
                if self.backend == ConsensusBackend::Raft {
                    // Term-bumped replay of the mirrored Raft log: the
                    // returned follower overwrite-accepts and applies the
                    // tail its snapshot predates.
                    self.raft_replay_to(core, ctx, peer, 0);
                } else {
                    self.replay_log_to(core, ctx, peer);
                }
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                if let Some(rl) = self.raft_leader.as_mut() {
                    rl.set_cluster_size(mb.live_set().len());
                }
            }
            MembershipEvent::LeaderSwitched => {
                if core.is_leader() {
                    ctx.metrics.elections += 1;
                    ctx.metrics.election_times.push(ctx.q.now());
                    if self.backend == ConsensusBackend::Raft {
                        // Stand-alone Raft takeover: adopt the accepted log
                        // at a higher term and re-replicate it as the lease
                        // campaign (followers overwrite-accept higher
                        // terms; their acks double as lease votes).
                        if core.system != SystemKind::Waverunner && self.raft_leader.is_none() {
                            self.ensure_raft_leader(core, ctx, mb);
                        }
                    } else {
                        // Take over: re-replicate our log suffix first — the
                        // crashed leader may have written an Accept to only a
                        // subset of followers (including us), and Mu's
                        // slot-adoption only repairs slots we later propose
                        // into. Idempotent: followers reject equal/lower
                        // proposals and skip already-applied slots. The
                        // Prepare phase is Mu's leadership confirmation:
                        // until a WriteProposal round reaches quorum this
                        // leadership is provisional (see mu_confirmed).
                        self.mu_confirmed = false;
                        let peers = mb.live_peers(core.id);
                        for peer in peers {
                            self.replay_log_to(core, ctx, peer);
                        }
                        for g in 0..self.mu.len() {
                            self.mu[g].set_cluster_size(mb.live_set().len());
                            let slot = self.logs[g].next_free_slot();
                            if let Some(round) = self.mu[g].pump(slot) {
                                self.fan_out_round(core, ctx, mb, g, round);
                            }
                        }
                    }
                }
                // Any of our forwards pending at the dead leader: retry now.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
        }
    }

    fn flush_pending(&mut self, plane: &mut Catalog) {
        for g in 0..self.logs.len() {
            for e in self.logs[g].drain_unapplied() {
                plane.apply_forced(&e.op);
            }
        }
    }

    fn snapshot_logs(&self) -> Vec<ReplicationLog> {
        self.logs.clone()
    }

    fn install_logs(&mut self, logs: Vec<ReplicationLog>) {
        self.logs = logs;
        if self.backend != ConsensusBackend::Raft {
            return;
        }
        // Raft recovery parity with Mu/Paxos: rebuild the follower
        // automaton from the donor's mirrored log. The installed plane
        // already contains every mirrored entry's effect, so the rebuilt
        // log starts fully applied; the leader's replay covers anything
        // committed after the snapshot point.
        let entries = self.logs.first().map(|l| l.entries_from(0)).unwrap_or_default();
        let term = entries.iter().map(|(_, e)| e.proposal).max().unwrap_or(1);
        let ops: Vec<OpCall> = entries.into_iter().map(|(_, e)| e.op).collect();
        self.raft_follower = RaftFollower::restore(term, ops);
        if self.system != SystemKind::Waverunner {
            // A recovered ex-leader rejoins as a follower (the donor's
            // leader view installs with the snapshot); stale pipeline
            // state must not answer ghosts of pre-crash requests.
            self.raft_leader = None;
        }
        self.raft_pending = FastMap::default();
        self.raft_lease = true;
        self.raft_votes = FastMap::default();
        self.raft_parked.clear();
    }

    fn replay_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, peer: NodeId) {
        // Heal-time anti-entropy: a short partition can open a silent gap
        // at `peer` (a round committed by the other majority members), so
        // the leader re-ships its committed log. Idempotent on every
        // backend: proposal-guarded slots (Mu) / overwrite-accept (Raft).
        if self.backend == ConsensusBackend::Raft {
            self.raft_replay_to(core, ctx, peer, 0);
        } else {
            self.replay_log_to(core, ctx, peer);
        }
    }

    fn abdicate_if_unconfirmed(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, rightful: NodeId) {
        if !core.is_leader() {
            return;
        }
        if self.backend == ConsensusBackend::Raft {
            if !self.raft_lease && self.raft_leader.is_some() {
                self.raft_abdicate(core, ctx, rightful);
            }
        } else if !self.mu_confirmed {
            self.mu_abdicate(core, ctx, rightful);
        }
    }

    fn debug_status(&self) -> String {
        let mu_q: usize = self.mu.iter().map(|m| m.queue_len()).sum();
        let mu_idle: Vec<bool> = self.mu.iter().map(|m| m.is_idle()).collect();
        format!(
            "pending_fwd={} requesters={} raft_pending={} raft_lease={} raft_parked={} mu_q={} mu_idle={:?}",
            self.pending_fwd.len(),
            self.requesters.len(),
            self.raft_pending.len(),
            self.raft_lease,
            self.raft_parked.len(),
            mu_q,
            mu_idle
        )
    }
}
