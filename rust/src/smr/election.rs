//! Leader Switch Plane (§4.4): heartbeat tracking, crash detection, and
//! smallest-live-ID leader election.
//!
//! Each replica keeps an RDMA-exposed heartbeat counter it increments
//! periodically; its Heartbeat Scanner RDMA-reads every other replica's
//! counter. A counter unchanged for `threshold` consecutive reads marks the
//! replica failed; a counter that moves again marks it recovered. If the
//! failed replica was the leader, the new leader is the smallest live ID
//! and every live replica performs a Permission Switch (Fig 13).

use crate::sim::NodeId;

#[derive(Clone, Copy, Debug, Default)]
struct PeerState {
    last_value: u64,
    unchanged: u32,
    alive: bool,
}

#[derive(Clone, Debug)]
pub struct HeartbeatTracker {
    me: NodeId,
    peers: Vec<PeerState>,
    threshold: u32,
}

/// What a heartbeat observation revealed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HbVerdict {
    Alive,
    /// Crossed the failure threshold on *this* observation.
    JustFailed,
    /// Already considered failed.
    StillDead,
    /// Was failed, counter moved again (§3: replicas may return).
    Recovered,
}

impl HeartbeatTracker {
    pub fn new(me: NodeId, n: usize, threshold: u32) -> Self {
        HeartbeatTracker {
            me,
            peers: vec![PeerState { last_value: 0, unchanged: 0, alive: true }; n],
            threshold,
        }
    }

    /// Feed one heartbeat read of `peer`.
    pub fn observe(&mut self, peer: NodeId, value: u64) -> HbVerdict {
        debug_assert_ne!(peer, self.me);
        let s = &mut self.peers[peer];
        if value != s.last_value {
            s.last_value = value;
            s.unchanged = 0;
            if !s.alive {
                s.alive = true;
                return HbVerdict::Recovered;
            }
            return HbVerdict::Alive;
        }
        if !s.alive {
            return HbVerdict::StillDead;
        }
        s.unchanged += 1;
        if s.unchanged >= self.threshold {
            s.alive = false;
            HbVerdict::JustFailed
        } else {
            HbVerdict::Alive
        }
    }

    /// A read that never completed (node crashed hard): counts as an
    /// unchanged observation.
    pub fn observe_timeout(&mut self, peer: NodeId) -> HbVerdict {
        let v = self.peers[peer].last_value;
        self.observe(peer, v)
    }

    pub fn is_alive(&self, peer: NodeId) -> bool {
        if peer == self.me {
            true
        } else {
            self.peers[peer].alive
        }
    }

    /// Live replica set as this replica sees it (self always included).
    pub fn live_set(&self) -> Vec<NodeId> {
        (0..self.peers.len()).filter(|&i| self.is_alive(i)).collect()
    }

    /// Election rule: the live replica with the smallest ID (§4.4).
    pub fn elect_leader(&self) -> NodeId {
        self.live_set().into_iter().min().expect("self is always live")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_after_threshold_unchanged_reads() {
        let mut t = HeartbeatTracker::new(1, 4, 3);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive); // unchanged #2
        assert_eq!(t.observe(0, 5), HbVerdict::JustFailed); // unchanged #3
        assert!(!t.is_alive(0));
        assert_eq!(t.observe(0, 5), HbVerdict::StillDead);
    }

    #[test]
    fn progressing_heartbeat_stays_alive() {
        let mut t = HeartbeatTracker::new(1, 2, 2);
        for v in 1..100 {
            assert_eq!(t.observe(0, v), HbVerdict::Alive);
        }
        assert!(t.is_alive(0));
    }

    #[test]
    fn recovery_detected() {
        let mut t = HeartbeatTracker::new(1, 2, 1);
        t.observe(0, 5);
        assert_eq!(t.observe(0, 5), HbVerdict::JustFailed);
        assert_eq!(t.observe(0, 6), HbVerdict::Recovered);
        assert!(t.is_alive(0));
    }

    #[test]
    fn elects_smallest_live_id() {
        let mut t = HeartbeatTracker::new(2, 4, 1);
        assert_eq!(t.elect_leader(), 0);
        t.observe(0, 0); // unchanged from initial 0 -> failed (threshold 1)
        assert_eq!(t.elect_leader(), 1);
        t.observe(1, 0);
        assert_eq!(t.elect_leader(), 2, "self is next smallest");
    }

    #[test]
    fn timeout_counts_as_unchanged() {
        let mut t = HeartbeatTracker::new(1, 2, 2);
        t.observe(0, 9);
        assert_eq!(t.observe_timeout(0), HbVerdict::Alive);
        assert_eq!(t.observe_timeout(0), HbVerdict::JustFailed);
    }
}
