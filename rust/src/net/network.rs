//! The network actor: turns issued verbs into delivery/ACK events with
//! fabric-calibrated latencies, enforcing reliable in-order delivery per
//! (src, dst) pair (the paper's network model, §3).

use crate::mem::MemParams;
use crate::net::fabric::FabricParams;
use crate::net::qp::QpTable;
use crate::net::verbs::{Verb, VerbKind};
use crate::sim::{EventKind, EventQueue, NodeId, Time};

/// Outcome of issuing a verb, as seen by the initiator.
#[derive(Clone, Copy, Debug)]
pub struct IssueOutcome {
    /// When the initiating compute element regains control.
    pub initiator_free_at: Time,
    /// When the payload is visible at the destination (None if nacked).
    pub delivered_at: Option<Time>,
}

#[derive(Debug)]
pub struct Network {
    mem: MemParams,
    /// In-order channel state: earliest next delivery time per (src, dst).
    channel_clear_at: Vec<Vec<Time>>,
    /// Separate lane for heartbeat-plane traffic (never queued behind bulk
    /// replication).
    hb_clear_at: Vec<Vec<Time>>,
    /// Crash state mirror (verbs to a crashed node vanish; no ACK).
    crashed: Vec<bool>,
    pub verbs_issued: u64,
    pub verbs_nacked: u64,
}

impl Network {
    pub fn new(n: usize, mem: MemParams) -> Self {
        Network {
            mem,
            channel_clear_at: vec![vec![0; n]; n],
            hb_clear_at: vec![vec![0; n]; n],
            crashed: vec![false; n],
            verbs_issued: 0,
            verbs_nacked: 0,
        }
    }

    pub fn set_crashed(&mut self, node: NodeId, crashed: bool) {
        self.crashed[node] = crashed;
    }

    pub fn is_crashed(&self, node: NodeId) -> bool {
        self.crashed[node]
    }

    pub fn mem(&self) -> &MemParams {
        &self.mem
    }

    /// Issue `verb` from `src` to `dst` at time `now` over `fabric`.
    ///
    /// Schedules `VerbDeliver` at the destination and, when the verb kind
    /// carries a completion, `AckDeliver`/`NackDeliver` back at the source.
    /// Returns initiator-side timing so the caller can advance its busy
    /// clock (Hamband blocks on the CQE; SafarDB only pays the issue cost).
    pub fn issue(
        &mut self,
        q: &mut EventQueue,
        qps: &QpTable,
        fabric: &FabricParams,
        now: Time,
        src: NodeId,
        dst: NodeId,
        verb: Verb,
        want_completion: bool,
    ) -> IssueOutcome {
        self.verbs_issued += 1;
        let bytes = verb.wire_bytes();
        let token = verb.token;

        // Permission check at the destination QPC. Only the follower's
        // leader-write QP is fenced by the Permission Switch (§4.4);
        // relaxed-path traffic rides per-peer QPs that stay open, and
        // one-sided reads are answered from memory regardless.
        let fenced = verb.leader_qp && !qps.is_open(src, dst);

        if fenced || self.crashed[dst] {
            self.verbs_nacked += 1;
            // Fenced QPs NACK after a round trip; a crashed destination
            // stalls the verb until the retransmission timeout expires.
            let nack_at = if self.crashed[dst] {
                now + fabric.crash_timeout_ns
            } else {
                now + fabric.ack_at_ns(bytes, verb.dst_mem, &self.mem)
            };
            if want_completion {
                q.push(nack_at, src, EventKind::NackDeliver { token });
            }
            let free_at = if fabric.wait_ack { nack_at } else { now + fabric.verb_issue_ns };
            return IssueOutcome { initiator_free_at: free_at, delivered_at: None };
        }

        let one_way = fabric.one_way_ns(bytes, verb.dst_mem, &self.mem);
        // Reliable in-order per channel: delivery can't overtake the
        // previous verb on the same (src, dst) pair. Heartbeat-plane verbs
        // ride their own lane.
        let clear = if verb.payload.is_heartbeat() {
            &mut self.hb_clear_at[src][dst]
        } else {
            &mut self.channel_clear_at[src][dst]
        };
        let deliver_at = (now + one_way).max(*clear + 1);
        *clear = deliver_at;

        let is_read = verb.kind == VerbKind::Read;
        q.push(deliver_at, dst, EventKind::VerbDeliver { src, verb });

        let ack_at = deliver_at + fabric.ack_overhead_ns;
        // Read verbs complete via the remote's ReadResp, not an ACK; they
        // still NACK above when fenced/crashed so initiators see failures.
        if want_completion && !is_read {
            q.push(ack_at, src, EventKind::AckDeliver { token });
        }
        let free_at = if fabric.wait_ack { ack_at } else { now + fabric.verb_issue_ns };
        IssueOutcome { initiator_free_at: free_at, delivered_at: Some(deliver_at) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::MemKind;
    use crate::net::verbs::Payload;

    fn setup(n: usize) -> (EventQueue, Network, QpTable, FabricParams) {
        (
            EventQueue::new(),
            Network::new(n, MemParams::default_params()),
            QpTable::full_mesh(n),
            FabricParams::fpga(),
        )
    }

    fn raw_write(token: u64) -> Verb {
        Verb::write(MemKind::Hbm, Payload::Raw { bytes: 64 }, token)
    }

    #[test]
    fn delivery_and_ack_scheduled() {
        let (mut q, mut net, qps, fab) = setup(2);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(7), true);
        assert!(out.delivered_at.is_some());
        let ev1 = q.pop().unwrap();
        assert!(matches!(ev1.kind, EventKind::VerbDeliver { src: 0, .. }));
        assert_eq!(ev1.dest, 1);
        let ev2 = q.pop().unwrap();
        assert!(matches!(ev2.kind, EventKind::AckDeliver { token: 7 }));
        assert_eq!(ev2.dest, 0);
        assert!(ev2.time > ev1.time);
    }

    #[test]
    fn in_order_delivery_per_channel() {
        let (mut q, mut net, qps, fab) = setup(2);
        // Issue a large verb then a tiny one: the tiny one must not overtake.
        let big = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 8192 }, 1);
        let tiny = Verb::write(MemKind::Reg, Payload::Raw { bytes: 1 }, 2);
        let d1 = net.issue(&mut q, &qps, &fab, 0, 0, 1, big, false).delivered_at.unwrap();
        let d2 = net.issue(&mut q, &qps, &fab, 5, 0, 1, tiny, false).delivered_at.unwrap();
        assert!(d2 > d1, "FIFO per (src,dst): {d2} <= {d1}");
    }

    #[test]
    fn closed_qp_nacks_writes() {
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(9).on_leader_qp(), true);
        assert!(out.delivered_at.is_none());
        let ev = q.pop().unwrap();
        assert!(matches!(ev.kind, EventKind::NackDeliver { token: 9 }));
        assert_eq!(net.verbs_nacked, 1);
    }

    #[test]
    fn reads_bypass_write_fencing() {
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let r = Verb::read(crate::net::verbs::ReadTarget::Heartbeat, 3);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, r, false);
        assert!(out.delivered_at.is_some(), "one-sided reads still answered");
    }

    #[test]
    fn relaxed_path_writes_unfenced() {
        // Only the leader-write QP is fenced (§4.4); relaxed RDT traffic
        // keeps flowing through a permission switch.
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(5), false);
        assert!(out.delivered_at.is_some());
    }

    #[test]
    fn crashed_destination_swallows_verbs() {
        let (mut q, mut net, qps, fab) = setup(2);
        net.set_crashed(1, true);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(4), true);
        assert!(out.delivered_at.is_none());
        assert!(matches!(q.pop().unwrap().kind, EventKind::NackDeliver { token: 4 }));
    }

    #[test]
    fn wait_ack_fabric_blocks_initiator() {
        let mut q = EventQueue::new();
        let mut net = Network::new(2, MemParams::default_params());
        let qps = QpTable::full_mesh(2);
        let fab = FabricParams::traditional();
        let out = net.issue(
            &mut q,
            &qps,
            &fab,
            0,
            0,
            1,
            Verb::write(MemKind::HostDram, Payload::Raw { bytes: 64 }, 1),
            true,
        );
        assert!(out.initiator_free_at > 1_900, "CQE wait: {}", out.initiator_free_at);
    }
}
