//! Integration: convergence + integrity across every RDT, both systems,
//! and every propagation mode — seeded-random property runs (util::prop).
//!
//! Convergence (all live replicas reach bit-identical state at quiescence)
//! and integrity (Table B.1 invariants hold) are the paper's correctness
//! claims; every experiment asserts them too, but these tests sweep the
//! configuration space much wider.

use safardb::config::{PropagationMode, SimConfig, SystemKind, WorkloadKind};
use safardb::engine::cluster;
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

fn all_kinds() -> Vec<RdtKind> {
    let mut v = RdtKind::crdt_benchmarks().to_vec();
    v.extend_from_slice(RdtKind::wrdt_benchmarks());
    v
}

#[test]
fn every_rdt_converges_on_safardb() {
    for rdt in all_kinds() {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
        cfg.total_ops = 12_000;
        cfg.update_pct = 30;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "{} diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{} violated integrity", rdt.name());
    }
}

#[test]
fn every_rdt_converges_on_hamband() {
    for rdt in all_kinds() {
        let mut cfg = SimConfig::hamband(WorkloadKind::Micro(rdt));
        cfg.total_ops = 8_000;
        cfg.update_pct = 30;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "{} diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{} violated integrity", rdt.name());
    }
}

#[test]
fn all_propagation_modes_converge() {
    let modes = [
        PropagationMode::WriteNoBuffer,
        PropagationMode::WriteBuffered,
        PropagationMode::Rpc,
    ];
    for red in modes {
        for con in [PropagationMode::WriteNoBuffer, PropagationMode::WriteThrough] {
            for rdt in [RdtKind::PnCounter, RdtKind::Account, RdtKind::Auction] {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
                cfg.prop_reducible = red;
                cfg.prop_irreducible = if red == PropagationMode::Rpc {
                    PropagationMode::Rpc
                } else {
                    PropagationMode::WriteNoBuffer
                };
                cfg.prop_conflicting = con;
                cfg.total_ops = 10_000;
                cfg.update_pct = 25;
                let rep = cluster::run(cfg);
                assert!(
                    rep.converged() && rep.invariants_ok,
                    "{} {red:?}/{con:?} failed",
                    rdt.name()
                );
            }
        }
    }
}

#[test]
fn prop_random_configs_converge() {
    // Seeded random sweep: rdt x system x nodes x update% x clients.
    prop::check("random-cluster-convergence", 0xfeed, 24, |rng| {
        let kinds = all_kinds();
        let rdt = *rng.choose(&kinds);
        let system = if rng.gen_bool(0.5) { SystemKind::SafarDb } else { SystemKind::Hamband };
        let mut cfg = match system {
            SystemKind::SafarDb => SimConfig::safardb(WorkloadKind::Micro(rdt)),
            _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
        };
        cfg.n_replicas = 3 + rng.gen_range(6) as usize;
        cfg.update_pct = 5 + rng.gen_range(45) as u8;
        cfg.clients_per_replica = 1 + rng.gen_range(6) as usize;
        cfg.total_ops = 4_000 + rng.gen_range(6_000);
        cfg.seed = rng.next_u64();
        let label = format!("{} {} n={} u={}", system.name(), rdt.name(), cfg.n_replicas, cfg.update_pct);
        let rep = cluster::run(cfg);
        prop_assert!(rep.converged(), "{label}: diverged {:?}", rep.digests);
        prop_assert!(rep.invariants_ok, "{label}: integrity violated");
        Ok(())
    });
}

#[test]
fn prop_summarization_preserves_state() {
    // Batching must change timing only, never the converged state value.
    prop::check("summarize-conservation", 0xbeef, 12, |rng| {
        let rdt = *rng.choose(&[RdtKind::PnCounter, RdtKind::Account, RdtKind::GSet]);
        let seed = rng.next_u64();
        let digest_at = |threshold: u32| {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
            cfg.summarize_threshold = threshold;
            cfg.total_ops = 6_000;
            cfg.update_pct = 40;
            cfg.seed = seed;
            let rep = cluster::run(cfg);
            assert!(rep.converged(), "{} t={threshold} diverged", rdt.name());
            // §5.4: batching defers coordination, so the balance invariant
            // can be transiently violated by stale-window debits — the
            // integrity/staleness trade-off the paper calls out. Conflict-
            // free types must always keep their (trivial) invariants.
            if !(rdt == RdtKind::Account && threshold > 1) {
                assert!(rep.invariants_ok, "{} t={threshold} invariant", rdt.name());
            }
            rep.digests[0]
        };
        let base = digest_at(1);
        let batched = digest_at(5);
        // Same seed => same issued ops => same converged state (counters
        // and deposits aggregate associatively; Account withdraw outcomes
        // can differ in *rejections* under different interleavings, so we
        // only require exact equality for conflict-free types).
        if rdt != RdtKind::Account {
            prop_assert!(base == batched, "{}: summarization changed state", rdt.name());
        }
        Ok(())
    });
}

#[test]
fn ycsb_and_smallbank_converge_across_systems() {
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        for system in [SystemKind::SafarDb, SystemKind::Hamband] {
            let mut cfg = match system {
                SystemKind::SafarDb => SimConfig::safardb(workload),
                _ => SimConfig::hamband(workload),
            };
            cfg.total_ops = 10_000;
            cfg.update_pct = 30;
            let rep = cluster::run(cfg);
            assert!(rep.converged() && rep.invariants_ok, "{} {:?}", system.name(), workload);
        }
    }
}

#[test]
fn waverunner_converges_and_only_leader_commits() {
    let mut cfg = SimConfig::waverunner(WorkloadKind::Ycsb);
    cfg.total_ops = 9_000;
    cfg.update_pct = 40;
    let rep = cluster::run(cfg);
    assert!(rep.converged());
    assert!(rep.metrics.smr_commits > 0, "PUTs go through Raft");
}

#[test]
fn determinism_same_seed_same_everything() {
    let make = || {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Auction));
        cfg.total_ops = 8_000;
        cfg.update_pct = 25;
        cfg.seed = 1234;
        cluster::run(cfg)
    };
    let a = make();
    let b = make();
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.metrics.events, b.metrics.events);
    assert_eq!(a.metrics.makespan_ns, b.metrics.makespan_ns);
    assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
}
