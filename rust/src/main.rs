//! SafarDB launcher.
//!
//! ```text
//! safardb expt <id|all> [--quick] [--threads N] [--backend mu|raft|paxos]
//!                                                 reproduce a paper table/figure
//! safardb list                                    list experiment ids
//! safardb run [config.kv] [k=v ...]               run one cluster config, print report
//! safardb runtime-check [dir]                     load + execute the kernel runtime
//! ```
//! (hand-rolled arg parsing: the offline crate set has no clap.)
//!
//! Sweep cells fan out over worker threads (`--threads N`, the
//! `SAFARDB_THREADS` environment variable, or all available cores, in that
//! order); tables are bit-identical for any thread count.

use safardb::config::{ConsensusBackend, SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::expt;
use safardb::rdt::RdtKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("expt") => cmd_expt(&args[1..]),
        Some("list") => {
            for id in expt::ALL {
                println!("{id}");
            }
            0
        }
        Some("run") => cmd_run(&args[1..]),
        Some("runtime-check") => cmd_runtime_check(&args[1..]),
        _ => {
            eprintln!("usage: safardb <expt|list|run|runtime-check> [...]");
            eprintln!("  expt <id|all> [--quick] [--threads N] [--backend mu|raft|paxos]");
            eprintln!("                           reproduce a paper table/figure (see `safardb list`)");
            eprintln!("  run [config.kv] [k=v]    run one cluster and print the report");
            eprintln!("  runtime-check [dir]      verify the kernel runtime loads and executes");
            2
        }
    };
    std::process::exit(code);
}

fn parse_threads(v: &str) -> Option<usize> {
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

fn parse_backend(v: &str) -> Option<ConsensusBackend> {
    ConsensusBackend::parse(v)
}

fn cmd_expt(args: &[String]) -> i32 {
    let mut quick = false;
    let mut threads: Option<usize> = None;
    let mut backend: Option<ConsensusBackend> = None;
    let mut ids: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if a == "--quick" {
            quick = true;
        } else if a == "--backend" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--backend requires a value (mu|raft|paxos)");
                return 2;
            };
            let Some(b) = parse_backend(v) else {
                eprintln!("bad --backend value '{v}' (want mu|raft|paxos)");
                return 2;
            };
            backend = Some(b);
        } else if let Some(v) = a.strip_prefix("--backend=") {
            let Some(b) = parse_backend(v) else {
                eprintln!("bad --backend value '{v}' (want mu|raft|paxos)");
                return 2;
            };
            backend = Some(b);
        } else if a == "--threads" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--threads requires a value");
                return 2;
            };
            let Some(n) = parse_threads(v) else {
                eprintln!("bad --threads value '{v}' (want a positive integer)");
                return 2;
            };
            threads = Some(n);
        } else if let Some(v) = a.strip_prefix("--threads=") {
            let Some(n) = parse_threads(v) else {
                eprintln!("bad --threads value '{v}' (want a positive integer)");
                return 2;
            };
            threads = Some(n);
        } else if a.starts_with("--") {
            eprintln!("unknown flag '{a}'");
            return 2;
        } else {
            ids.push(a);
        }
        i += 1;
    }
    if let Some(n) = threads {
        expt::common::set_threads(n);
    }
    if let Some(b) = backend {
        // Only the backend-aware sweeps (`backends`, `chaos`) consult the
        // filter; accepting it elsewhere would silently emit unfiltered
        // (default-backend) CSVs under a backend-filtered invocation.
        let ids_for_check: Vec<&str> = if ids.is_empty() || ids == ["all"] {
            expt::ALL.to_vec()
        } else {
            ids.clone()
        };
        if ids_for_check.iter().any(|id| {
            !matches!(expt::canonical(id), Some("backends") | Some("chaos") | Some("scaleout"))
        }) {
            eprintln!("--backend only applies to `expt backends`, `expt chaos`, and `expt scaleout`");
            return 2;
        }
        expt::common::set_backend_filter(b);
        eprintln!("[backend filter: {}]", b.name());
    }
    eprintln!("[sweep executor: {} worker thread(s)]", expt::common::configured_threads());
    let ids: Vec<&str> = if ids.is_empty() || ids == ["all"] {
        expt::ALL.to_vec()
    } else {
        ids
    };
    for id in ids {
        // Save under the canonical id so `expt fig06` and `expt all` write
        // the same results/ filenames.
        let Some(canon) = expt::canonical(id) else {
            eprintln!("unknown experiment '{id}'; try `safardb list`");
            return 2;
        };
        let Some(tables) = expt::run(canon, quick) else {
            // Reachable only if expt::ALL and run()'s dispatch drift apart.
            eprintln!("experiment '{canon}' is listed but has no dispatch arm");
            return 2;
        };
        for t in &tables {
            println!("{}", t.render());
        }
        expt::common::save(&tables, canon);
        println!("[saved results/{canon}*.csv]\n");
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
    for a in args {
        if a.ends_with(".kv") || a.contains('/') {
            match std::fs::read_to_string(a) {
                Ok(body) => {
                    if let Err(e) = cfg.apply_kv(&body) {
                        eprintln!("{a}: {e}");
                        return 2;
                    }
                }
                Err(e) => {
                    eprintln!("{a}: {e}");
                    return 2;
                }
            }
        } else if let Some((k, v)) = a.split_once('=') {
            if let Err(e) = cfg.apply_kv(&format!("{k} = {v}")) {
                eprintln!("{e}");
                return 2;
            }
        } else if a.to_lowercase() == "mixed" {
            // Multi-tenant catalog scenario: heterogeneous objects behind
            // one data plane (equivalent to `objects=mixed`).
            cfg.objects = safardb::config::CatalogSpec::mixed();
        } else {
            // workload selector: rdt name / ycsb / smallbank
            cfg.workload = match a.to_lowercase().as_str() {
                "ycsb" => WorkloadKind::Ycsb,
                "smallbank" => WorkloadKind::SmallBank,
                "pn-counter" | "pncounter" => WorkloadKind::Micro(RdtKind::PnCounter),
                "lww" | "lww-register" => WorkloadKind::Micro(RdtKind::LwwRegister),
                "g-set" | "gset" => WorkloadKind::Micro(RdtKind::GSet),
                "pn-set" | "pnset" => WorkloadKind::Micro(RdtKind::PnSet),
                "2p-set" | "2pset" => WorkloadKind::Micro(RdtKind::TwoPSet),
                "account" => WorkloadKind::Micro(RdtKind::Account),
                "courseware" => WorkloadKind::Micro(RdtKind::Courseware),
                "project" => WorkloadKind::Micro(RdtKind::Project),
                "movie" => WorkloadKind::Micro(RdtKind::Movie),
                "auction" => WorkloadKind::Micro(RdtKind::Auction),
                other => {
                    eprintln!("unknown workload '{other}'");
                    return 2;
                }
            };
        }
    }
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e}");
        return 2;
    }
    let sys = cfg.system;
    let backend = cfg.backend;
    let batch = cfg.batch_size;
    let name = if cfg.objects.is_default() {
        cfg.workload.name()
    } else {
        format!("catalog[{}] ({} objects)", cfg.objects.label(), cfg.n_objects())
    };
    let rep = cluster::run(cfg);
    println!("system      : {}", sys.name());
    println!("backend     : {} (batch {})", backend.name(), batch);
    println!("workload    : {name}");
    println!(
        "response    : {:.3} us (p50 {:.3}, p99 {:.3})",
        rep.response_us(),
        rep.metrics.response.p50() as f64 / 1000.0,
        rep.metrics.response.p99() as f64 / 1000.0
    );
    println!("throughput  : {:.3} OPs/us", rep.throughput());
    println!("power       : {:.1} W", rep.power.total_w());
    println!("converged   : {}", rep.converged());
    println!("invariants  : {}", rep.invariants_ok);
    println!("smr commits : {}", rep.metrics.smr_commits);
    println!("rejected    : {}", rep.metrics.rejected);
    println!("elections   : {}", rep.metrics.elections);
    println!(
        "sim events  : {} ({:.2}M events/s wall)",
        rep.metrics.events,
        rep.metrics.events as f64 / rep.wall_s.max(1e-9) / 1e6
    );
    if rep.converged() && rep.invariants_ok {
        0
    } else {
        1
    }
}

fn cmd_runtime_check(args: &[String]) -> i32 {
    let dir = args.first().map(String::as_str).unwrap_or(safardb::runtime::DEFAULT_ARTIFACTS);
    match safardb::runtime::Runtime::load(dir) {
        Ok(rt) => {
            // Absent AOT artifacts are not an error: the reference executor
            // runs on builtin signatures (platform() says which happened).
            println!("platform : {}", rt.platform());
            println!("artifacts: {:?}", rt.names());
            let mut acc = safardb::runtime::Accelerator::new(rt);
            let v = acc
                .pn_counter_merge(&[vec![1.0, 2.0], vec![3.0, 4.0]], &[vec![0.5; 2], vec![0.5; 2]])
                .expect("pn_counter_merge");
            assert_eq!(v, vec![3.0, 5.0]);
            println!("pn_counter_merge OK ({} calls)", acc.calls());
            0
        }
        Err(e) => {
            eprintln!("runtime load failed: {e:#}");
            1
        }
    }
}
