//! Deterministic PRNG (splitmix64 seeding + xoshiro256++), plus the
//! distribution helpers the simulator needs (uniform, exponential,
//! lognormal, Zipfian). Everything is reproducible from a single `u64`
//! seed, which every experiment and property test reports on failure.

/// xoshiro256++ PRNG. Not cryptographic; fast, 2^256-1 period,
/// deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per replica) from this RNG.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Lemire's unbiased bounded generation.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// True with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Exponentially distributed with the given mean.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.gen_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = 1.0 - self.gen_f64();
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given median and sigma (of the underlying normal).
    /// Used for the traditional-RDMA permission-switch latency (Fig 13's
    /// "high variability" histogram).
    pub fn gen_lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.gen_normal()).exp()
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipfian generator over `[0, n)` with parameter `theta` (θ=0 is uniform),
/// using the Gray et al. rejection-free method YCSB uses. θ here matches the
/// paper's Fig 16 x-axis (0 … 2).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        if theta <= 1e-9 {
            return Zipf { n, theta: 0.0, alpha: 0.0, zetan: 0.0, eta: 0.0, zeta2: 0.0 };
        }
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2.min(n), theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta, zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Direct sum for small n; Euler–Maclaurin tail approximation above.
        const EXACT: u64 = 100_000;
        if n <= EXACT {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=EXACT).map(|i| 1.0 / (i as f64).powf(theta)).sum();
            let a = EXACT as f64;
            let b = n as f64;
            let tail = if (theta - 1.0).abs() < 1e-9 {
                (b / a).ln()
            } else {
                (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta)
            };
            head + tail
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.theta == 0.0 {
            return rng.gen_range(self.n);
        }
        let u = rng.gen_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let spread = (self.n as f64) * (self.eta * u - self.eta + 1.0).powf(self.alpha);
        (spread as u64).min(self.n - 1)
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    #[allow(dead_code)]
    fn debug_consts(&self) -> (f64, f64) {
        (self.zeta2, self.zetan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Rng::new(3);
        let mean: f64 = (0..20_000).map(|_| rng.gen_exp(5.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 5.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn lognormal_median_close() {
        let mut rng = Rng::new(4);
        let mut v: Vec<f64> = (0..20_001).map(|_| rng.gen_lognormal(250.0, 0.6)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[10_000];
        assert!((med - 250.0).abs() < 20.0, "median={med}");
    }

    #[test]
    fn zipf_uniform_when_theta_zero() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Rng::new(5);
        let mut counts = [0u64; 10];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn zipf_skews_head_with_theta() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Rng::new(6);
        let mut head = 0u64;
        for _ in 0..10_000 {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // With θ≈1, the top 1% of keys should draw a large share of accesses.
        assert!(head > 4_000, "head={head}");
    }

    #[test]
    fn zipf_higher_theta_more_skew() {
        let mut rng = Rng::new(7);
        let mut top_share = |theta: f64| {
            let z = Zipf::new(1000, theta);
            let mut head = 0u64;
            for _ in 0..20_000 {
                if z.sample(&mut rng) == 0 {
                    head += 1;
                }
            }
            head
        };
        let low = top_share(0.5);
        let high = top_share(1.5);
        assert!(high > low * 2, "low={low} high={high}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
