//! Fig 16: Zipfian skew in hybrid mode — θ ∈ [0, 2], update ratios
//! 0/5/50 %, FPGA shares 20 % and 80 %.
//!
//! Expected shape: skew helps most when reads dominate AND most requests go
//! to host-resident keys (CPU cache locality: paper 2.5× RT / 2.3× tput at
//! 0 % writes, 20 % FPGA, θ 0→1.2); the benefit fades at 80 % FPGA share or
//! higher write ratios.

use crate::config::{HybridConfig, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::util::table::Table;

const THETAS: &[f64] = &[0.0, 0.6, 1.2, 2.0];
const WRITES: &[u8] = &[0, 5, 50];
const FPGA_PCTS: &[u8] = &[20, 80];

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        let mut t = Table::new(
            &format!("Fig 16 — Zipfian skew on {} (hybrid)", workload.name()),
            &["theta", "upd%", "fpga_ops%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for &theta in THETAS {
            for &u in WRITES {
                for &pct in FPGA_PCTS {
                    if quick && (u == 5 || theta == 0.6) {
                        continue;
                    }
                    let mut cfg = SimConfig::safardb(workload);
                    cfg.n_replicas = 4;
                    cfg.update_pct = u;
                    let mut h = match workload {
                        WorkloadKind::Ycsb => HybridConfig::ycsb_default(),
                        _ => HybridConfig::smallbank_default(),
                    };
                    h.fpga_ops_pct = pct;
                    h.zipf_theta = theta;
                    cfg.hybrid = Some(h);
                    jobs.push(((theta, u, pct), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((theta, u, pct), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                format!("{theta:.1}"),
                u.to_string(),
                pct.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(t: &Table, theta: &str, upd: &str, pct: &str) -> f64 {
        t.rows()
            .iter()
            .find(|r| r[0] == theta && r[1] == upd && r[2] == pct)
            .unwrap()[3]
            .parse()
            .unwrap()
    }

    #[test]
    fn skew_helps_host_heavy_reads_most() {
        let t = &run(true)[0]; // YCSB
        let gain_host = rt(t, "0.0", "0", "20") / rt(t, "1.2", "0", "20");
        let gain_fpga = rt(t, "0.0", "0", "80") / rt(t, "1.2", "0", "80");
        assert!(gain_host > 1.3, "read-heavy host-heavy skew gain {gain_host} (paper 2.5x; ratio compressed by PCIe floor — EXPERIMENTS.md)");
        assert!(gain_host > gain_fpga, "host-heavy benefits more: {gain_host} vs {gain_fpga}");
        let gain_writes = rt(t, "0.0", "50", "20") / rt(t, "1.2", "50", "20");
        assert!(gain_writes < gain_host, "writes dampen the skew benefit");
    }
}
