"""AOT exporter: lower every Layer-2 entry to HLO *text* artifacts.

HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Emits artifacts/<name>.hlo.txt per entry plus artifacts/manifest.txt with
one line per entry:  name;in=<dtype><shape>,...;out=<dtype><shape>,...
which rust/src/runtime/artifacts.rs parses.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import EXPORTS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(spec) -> str:
    dt = str(spec.dtype)
    shape = "x".join(str(d) for d in spec.shape)
    return f"{dt}[{shape}]"


def export_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, in_specs) in EXPORTS.items():
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        ins = ",".join(_sig(s) for s in in_specs)
        out_sig = ",".join(_sig(s) for s in outs)
        manifest_lines.append(f"{name};in={ins};out={out_sig}")
        print(f"  {name}: {len(text)} chars, out=({out_sig})")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    lines = export_all(args.out)
    print(f"wrote {len(lines)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
