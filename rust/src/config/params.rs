//! Per-system parameter bundles: fabric + memory + execution + power.
//!
//! `ExecParams` captures where a system's RDT engine runs (FPGA user kernel
//! vs host CPU) and what its per-transaction compute costs are;
//! `PowerParams` feeds the §5.5 power model.

use crate::mem::{MemKind, MemParams};
use crate::net::fabric::FabricParams;

/// Which consensus engine serves the strongly-ordered path (§4.3–§4.4).
///
/// The `ReplicationPath` seam (engine/path.rs) makes the ordering protocol
/// a plug-in: Mu is the paper's latency-optimized SMR, Raft is the
/// Waverunner baseline's pipeline (also selectable stand-alone), and Paxos
/// is an APUS-style RDMA Multi-Paxos — the leader writes log entries into
/// per-follower landing regions with one-sided verbs and counts doorbell
/// (write-completion) ACKs toward a majority quorum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsensusBackend {
    /// Mu SMR: Prepare (read min-proposals / write proposal / read slots)
    /// then Accept, one round pipeline per synchronization group.
    Mu,
    /// Raft-style leader pipeline: AppendEntries fan-out, logical ACK
    /// verbs, majority commit (Waverunner's strong path, §5.2).
    Raft,
    /// APUS-style RDMA Paxos: one-sided log writes into follower landing
    /// regions; quorum = majority of write completions (doorbells).
    Paxos,
}

impl ConsensusBackend {
    pub const ALL: [ConsensusBackend; 3] =
        [ConsensusBackend::Mu, ConsensusBackend::Raft, ConsensusBackend::Paxos];

    pub fn name(&self) -> &'static str {
        match self {
            ConsensusBackend::Mu => "mu",
            ConsensusBackend::Raft => "raft",
            ConsensusBackend::Paxos => "paxos",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mu" => Some(ConsensusBackend::Mu),
            "raft" => Some(ConsensusBackend::Raft),
            "paxos" => Some(ConsensusBackend::Paxos),
            _ => None,
        }
    }
}

/// Execution-cost model for the replica's compute element.
#[derive(Clone, Copy, Debug)]
pub struct ExecParams {
    /// Fixed per-transaction pipeline cost (decode + ALU), excluding memory.
    pub op_exec_ns: u64,
    /// Per-request software overhead (parse, dispatch, locking). FPGA: the
    /// dispatcher is wires, so this is a few ns; CPU: function-call and
    /// cache-pressure reality.
    pub software_overhead_ns: u64,
    /// Where the object state lives.
    pub state_mem: MemKind,
    /// Client ingress + response-egress overhead per completed op.
    pub client_overhead_ns: u64,
    /// Cost of re-arming / servicing a background poller tick.
    pub poll_tick_ns: u64,
}

/// Activity-based power model inputs (§5.5, Fig 27).
#[derive(Clone, Copy, Debug)]
pub struct PowerParams {
    /// Static floor: FPGA fabric + HBM, or CPU package idle.
    pub static_w: f64,
    /// I/O subsystem static (RNIC + PCIe + DRAM for the CPU system; the
    /// FPGA card's CMAC is inside static_w).
    pub io_static_w: f64,
    /// Dynamic energy per executed transaction (nJ).
    pub op_nj: f64,
    /// Dynamic energy per verb on the wire (nJ).
    pub verb_nj: f64,
}

/// Everything latency/energy about one system under test.
#[derive(Clone, Copy, Debug)]
pub struct SystemParams {
    pub fabric: FabricParams,
    pub mem: MemParams,
    pub exec: ExecParams,
    pub power: PowerParams,
}

impl SystemParams {
    /// SafarDB: RDT engine in the FPGA user kernel, state in BRAM.
    pub fn safardb() -> Self {
        SystemParams {
            fabric: FabricParams::fpga(),
            mem: MemParams::default_params(),
            exec: ExecParams {
                op_exec_ns: 20,
                software_overhead_ns: 4,
                state_mem: MemKind::Bram,
                // Client requests arrive over the same 100 GbE port: packet
                // ingress + dispatch + response egress.
                client_overhead_ns: 240,
                poll_tick_ns: 6,
            },
            power: PowerParams {
                static_w: 27.0, // U280 fabric + HBM + CMAC
                io_static_w: 6.0,
                op_nj: 35.0,
                verb_nj: 20.0,
            },
        }
    }

    /// Hamband: RDT engine on the host CPU, state in DRAM, traditional RNIC.
    pub fn hamband() -> Self {
        SystemParams {
            fabric: FabricParams::traditional(),
            mem: MemParams::default_params(),
            exec: ExecParams {
                op_exec_ns: 55,
                software_overhead_ns: 170,
                state_mem: MemKind::HostDram,
                client_overhead_ns: 230,
                poll_tick_ns: 90,
            },
            power: PowerParams {
                static_w: 92.0,   // Sapphire Rapids package under load floor
                io_static_w: 52.0, // DDR5 + NDR200 RNIC + PCIe (paper: ~1/3 I/O)
                op_nj: 480.0,
                verb_nj: 160.0,
            },
        }
    }

    /// Waverunner: FPGA SmartNIC accelerates the Raft replication path,
    /// but the *application runs in host software* (§5.2) — so execution
    /// costs are CPU-like while the replication fabric is FPGA-like.
    pub fn waverunner() -> Self {
        let mut fabric = FabricParams::fpga();
        fabric.supports_rpc = false; // stock SmartNIC verbs only
        // SmartNIC: NIC-side fast path still crosses PCIe to reach the
        // host-resident application state.
        fabric.remote_landing_ns = 430;
        SystemParams {
            fabric,
            mem: MemParams::default_params(),
            exec: ExecParams {
                op_exec_ns: 55,
                software_overhead_ns: 170,
                state_mem: MemKind::HostDram,
                client_overhead_ns: 230,
                poll_tick_ns: 90,
            },
            power: PowerParams {
                static_w: 85.0,
                io_static_w: 45.0,
                op_nj: 430.0,
                verb_nj: 60.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safardb_is_near_memory() {
        let s = SystemParams::safardb();
        assert_eq!(s.exec.state_mem, MemKind::Bram);
        assert!(s.exec.software_overhead_ns < 10);
        assert!(s.fabric.supports_rpc);
        assert!(!s.fabric.wait_ack);
    }

    #[test]
    fn hamband_is_host_resident() {
        let h = SystemParams::hamband();
        assert_eq!(h.exec.state_mem, MemKind::HostDram);
        assert!(h.fabric.wait_ack);
        assert!(!h.fabric.supports_rpc);
    }

    #[test]
    fn waverunner_mixes_fpga_fabric_with_host_exec() {
        let w = SystemParams::waverunner();
        assert!(!w.fabric.wait_ack, "SmartNIC pipeline");
        assert_eq!(w.exec.state_mem, MemKind::HostDram, "app in software");
        assert!(w.fabric.remote_landing_ns > 0, "PCIe hop to host state");
    }

    #[test]
    fn backend_names_round_trip() {
        for b in ConsensusBackend::ALL {
            assert_eq!(ConsensusBackend::parse(b.name()), Some(b));
        }
        assert_eq!(ConsensusBackend::parse("PAXOS"), Some(ConsensusBackend::Paxos));
        assert_eq!(ConsensusBackend::parse("epaxos"), None);
    }

    #[test]
    fn power_floors_match_paper_scale() {
        // §5.5: SafarDB ~35 W vs Hamband ~160 W before dynamic power.
        let s = SystemParams::safardb().power;
        let h = SystemParams::hamband().power;
        assert!((30.0..40.0).contains(&(s.static_w + s.io_static_w)));
        assert!((130.0..165.0).contains(&(h.static_w + h.io_static_w)));
    }
}
