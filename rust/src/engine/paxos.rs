//! APUS-style RDMA-Paxos strong path — the third consensus backend behind
//! the [`ReplicationPath`] seam (`backend = paxos`), and the proof that a
//! new ordering engine drops in without touching the coordinator.
//!
//! Protocol (stable-leader fast path):
//! * the leader executes a conflicting op in total order (authoritative
//!   permissibility, like Mu's Accept), appends it to its log, and writes
//!   the entry batch into every follower's *landing region* with one
//!   one-sided verb per follower (`Payload::PaxosAppend`, leader-write QP);
//! * followers are passive memory on the critical path: the ACK is the
//!   write completion itself (the doorbell), and a majority of completions
//!   commits the batch — no logical ack verbs, no follower CPU;
//! * followers' landing regions apply commit-gated: entries are drained at
//!   quiescence (or when a follower is promoted/recovered), never
//!   speculatively, so a leadership change can truncate an uncommitted
//!   tail without un-applying state;
//! * on leader failure the smallest-live-ID replica takes over (the
//!   Permission Switch fences the deposed leader's QP), adopts a higher
//!   ballot, drains its own log, and mirrors it to every peer with one
//!   `Payload::PaxosReplay` (an exact-log overwrite, possibly empty).
//!
//! Per-path batching is native here: up to `batch_size` queued entries
//! ride one landing-region write.

use crate::config::SimConfig;
use crate::engine::path::{
    Membership, MembershipEvent, PendingClient, ReplicaCore, ReplicationPath, Requester,
    Submission, TokenCtx,
};
use crate::engine::store::Catalog;
use crate::engine::Ctx;
use crate::net::verbs::{Payload, Verb};
use crate::rdt::OpCall;
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::smr::paxos::{PaxosAcceptor, PaxosLeader, PaxosStep};
use crate::util::hasher::FastMap;
use crate::workload::WorkItem;

/// Completion tokens owned by the Paxos path.
#[derive(Clone, Copy, Debug)]
pub enum PaxosToken {
    /// Doorbell for one follower's landing-region write: the consensus
    /// shard (global sync group; 0 under `placement = single`), ballot +
    /// round nonce at issue time (the nonce rejects doorbells from
    /// stalled, re-pumped rounds that repeat ballot and slots) + the
    /// batch's first slot.
    Append { group: u8, ballot: u64, round: u64, start_slot: u64 },
    /// Forwarded conflicting op awaiting a LeaderReply.
    Forward { request_id: u64 },
    /// Leadership-lease probe doorbell (a takeover replay write). The wave
    /// nonce discards votes from a superseded campaign.
    Lease { group: u8, wave: u64 },
}

/// One Paxos consensus instance. Under `placement = single` there is one
/// shard with one total log (all catalog objects and sync groups share the
/// order — strictly stronger than Mu's per-group orders); sharded
/// placements give every global sync group its own instance with its own
/// ballot space, acceptor, lease, and landing-region pipeline.
struct PaxosShard {
    /// Entries carry their `ObjectId` inside the `OpCall`, so apply
    /// routes each to its catalog object.
    log: ReplicationLog,
    leader_sm: PaxosLeader,
    acceptor: PaxosAcceptor,
    /// Leadership lease: a promoted leader's takeover replay writes double
    /// as lease probes — a majority of doorbells confirms the cluster's
    /// permission switches accepted this leadership. Until then
    /// submissions park (no apply, no append), so a fenced partition-side
    /// imposter mutates nothing and abdicates cleanly once a smaller live
    /// node is back in view. The boot leader holds the lease.
    lease: bool,
    lease_wave: u64,
    lease_votes: u32,
    parked: Vec<(OpCall, Requester)>,
    /// Leader side: slot -> who to answer at commit.
    requesters: FastMap<u64, Requester>,
}

pub struct PaxosPath {
    shards: Vec<PaxosShard>,
    batch: usize,
    /// Round-commit telemetry: `(shard, start_slot)` -> virtual ns of the
    /// round's first fan-out. `or_insert` keeps the first attempt's stamp
    /// across stall/reset re-pumps, so `smr_round` reports true
    /// first-issue-to-commit latency.
    round_start: FastMap<(usize, u64), u64>,
    /// Chaos mode (link faults in the schedule): forwarded ops arm a
    /// reply watchdog, since a LeaderReply lost on a faulty link would
    /// otherwise strand its origin-side client slot forever.
    chaos: bool,
    /// Chaos-mode exactly-once ledger for forwarded ops (see
    /// `engine::strong`): verdicts of already-ordered `(origin, seq)`
    /// pairs, so a re-forward after a lost reply does not execute twice.
    done_fwd: FastMap<(usize, u64), bool>,
    /// Origin side: forwarded ops awaiting replies.
    pending_fwd: FastMap<u64, PendingClient>,
    next_request_id: u64,
    /// Per-group leadership view this path last acted on (diffed on
    /// `GroupLeadersChanged`; unused under `placement = single`).
    led: Vec<bool>,
}

impl PaxosPath {
    pub fn new(cfg: &SimConfig, id: NodeId, groups: usize) -> Self {
        let sharded = cfg.placement.is_sharded();
        let table = crate::smr::election::PlacementTable::new(cfg.placement, groups, cfg.n_replicas);
        let n_shards = if sharded { groups.max(1) } else { 1 };
        let shards = (0..n_shards)
            .map(|_| PaxosShard {
                log: ReplicationLog::new(),
                leader_sm: PaxosLeader::with_window(
                    id,
                    cfg.n_replicas,
                    cfg.batch_size as usize,
                    cfg.window as usize,
                ),
                acceptor: PaxosAcceptor::new(),
                lease: true,
                lease_wave: 0,
                lease_votes: 0,
                parked: Vec::new(),
                requesters: FastMap::default(),
            })
            .collect();
        PaxosPath {
            shards,
            batch: cfg.batch_size as usize,
            round_start: FastMap::default(),
            chaos: cfg.fault.has_link_faults(),
            done_fwd: FastMap::default(),
            pending_fwd: FastMap::default(),
            next_request_id: 1,
            led: (0..groups).map(|g| table.leader_of(g) == id).collect(),
        }
    }

    /// Shard index for global group `g`: identity under sharded
    /// placements, the one shared shard otherwise.
    fn sidx(&self, g: usize) -> usize {
        if self.shards.len() > 1 {
            g
        } else {
            0
        }
    }

    /// One lease-campaign wave. The first wave mirrors our log to every
    /// live peer with a completion-tracked replay write (takeover
    /// anti-entropy and probe in one); retry waves send an empty append
    /// probe instead — the doorbell is the vote, the log already shipped,
    /// and an empty *replay* would truncate a voter's log. A follower
    /// whose permission switch elected us lets the write through; everyone
    /// else fences it. Solo leaders grant themselves the lease.
    fn paxos_campaign(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize, first: bool) {
        self.shards[s].lease_wave += 1;
        self.shards[s].lease_votes = 0;
        if mb.live_set().len() / 2 == 0 {
            self.paxos_grant_lease(core, ctx, mb, s);
            return;
        }
        let group = s as u8;
        let wave = self.shards[s].lease_wave;
        let ballot = self.shards[s].leader_sm.ballot;
        // One shared batch for the whole campaign fan-out: each per-peer
        // clone is a refcount bump (§Perf).
        let ops: crate::net::verbs::OpBatch = if first {
            self.shards[s].log.entries_from(0).into_iter().map(|(_, e)| e.op).collect::<Vec<_>>().into()
        } else {
            Vec::new().into()
        };
        for peer in mb.live_peers(core.id) {
            let tok = core.token(TokenCtx::Paxos(PaxosToken::Lease { group, wave }));
            let payload = if first {
                Payload::PaxosReplay { group, ballot, ops: ops.clone() }
            } else {
                Payload::PaxosAppend { group, ballot, start_slot: 0, ops: ops.clone() }
            };
            let verb = Verb::write(core.landing_mem_for_peer(), payload, tok).on_leader_qp();
            ctx.metrics.verbs += 1;
            ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, true);
        }
        // Campaign-retry chain: probes are fenced at followers whose
        // permission switch has not happened yet.
        ctx.q.push(
            ctx.q.now() + core.heartbeat_period_ns,
            core.id,
            EventKind::Timer(TimerKind::SmrTick(s as u8)),
        );
    }

    /// Majority confirmed: adopt the ballot locally, execute our accepted
    /// tail, and serve — first the submissions that parked during the
    /// campaign, then normal traffic.
    fn paxos_grant_lease(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize) {
        self.shards[s].lease = true;
        let ballot = self.shards[s].leader_sm.ballot;
        self.shards[s].acceptor.accept(ballot);
        self.drain_own_log(core, ctx, s);
        self.shards[s].leader_sm.set_cluster_size(mb.live_set().len());
        let parked = std::mem::take(&mut self.shards[s].parked);
        for (op, req) in parked {
            self.leader_submit(core, ctx, mb, op, req);
        }
        self.try_fan_out(core, ctx, mb, s);
    }

    /// A promoted-but-unleased "leader" learned the rightful leader is
    /// someone else (the partition healed; we were the minority imposter).
    /// Nothing was applied or appended while parked — not even the
    /// acceptor promise moved, so the rightful leader's writes were never
    /// rejected here. Abdication is a pure re-route of the parked ops.
    /// Sharded placements hand over one shard (per-group refence keeps
    /// grants for groups that never moved); single placement switches the
    /// global leader QP.
    fn paxos_abdicate(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, rightful: NodeId) {
        if core.placement.is_sharded() {
            core.group_leaders[s] = rightful;
            ctx.qps.refence(core.id, &core.group_leaders);
            if let Some(l) = self.led.get_mut(s) {
                *l = false;
            }
        } else {
            ctx.qps.switch_leader(core.id, core.leader, rightful);
            core.leader = rightful;
        }
        self.shards[s].lease = true; // inert until the next promotion resets it
        // Pull the committed log we may have missed while self-elected.
        core.request_sync(ctx, rightful);
        let parked = std::mem::take(&mut self.shards[s].parked);
        for (op, req) in parked {
            match req {
                Requester::Local { .. } => self.forward_to_leader(core, ctx, op, req),
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false)
                }
            }
        }
    }

    /// Leader-side entry: execute in total order, append, replicate —
    /// within the op's consensus shard (always shard 0 under
    /// `placement = single`).
    fn leader_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        let s = self.sidx(core.plane.global_group(&op) as usize);
        if !self.shards[s].lease {
            // Leadership not confirmed by a doorbell majority yet: park.
            self.shards[s].parked.push((op, req));
            return;
        }
        if !core.plane.permissible(&op) {
            core.note_rejected(&op);
            if self.chaos {
                self.done_fwd.insert((op.origin, op.seq), false);
            }
            self.answer_requester(core, ctx, req, false);
            return;
        }
        let exec_cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), exec_cost);
        core.plane.apply(&op);
        core.executions += 1;
        let shard = &mut self.shards[s];
        let slot = shard.log.next_free_slot();
        shard.log.write_slot(slot, shard.leader_sm.ballot, op);
        shard.log.applied_upto = shard.log.applied_upto.max(slot + 1);
        shard.requesters.insert(slot, req);
        shard.leader_sm.submit(slot, op);
        self.try_fan_out(core, ctx, mb, s);
    }

    /// Pump queued batches until the window fills: one landing-region
    /// write fan-out per free pipeline stage.
    fn try_fan_out(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize) {
        let mut pumped = false;
        loop {
            let Some((ballot, round, start_slot, ops)) = self.shards[s].leader_sm.pump() else {
                break;
            };
            pumped = true;
            // The leader stays execution-busy through the round's issue,
            // exactly like Mu (appendix D.1 — leader-bound throughput);
            // windowed rounds then overlap their fabric round-trips.
            let now = ctx.q.now();
            if now > core.busy_until {
                core.busy_total += now - core.busy_until;
                core.busy_until = now;
            }
            // Batch assembly: one log read per coalesced entry (the
            // verb-issue setup is charged once by the fan_out below).
            let per_entry = core.sys.mem.local_read_ns(core.landing_mem());
            core.occupy_batch(now, per_entry, ops.len());
            if ops.len() > 1 {
                ctx.metrics.coalesced += ops.len() as u64 - 1;
            }
            let peers = mb.live_peers(core.id);
            self.shards[s].leader_sm.round_started(peers.len() as u32);
            self.round_start.entry((s, start_slot)).or_insert(now);
            ctx.metrics.note_inflight(s, self.shards[s].leader_sm.depth() as u64);
            let mem = core.landing_mem_for_peer();
            let group = s as u8;
            // Shared batch: the per-peer clone below is a refcount bump
            // (§Perf).
            let ops: crate::net::verbs::OpBatch = ops.into();
            core.fan_out(
                ctx,
                &peers,
                |t| {
                    Verb::write(
                        mem,
                        Payload::PaxosAppend { group, ballot, start_slot, ops: ops.clone() },
                        t,
                    )
                    .on_leader_qp()
                },
                true,
                || TokenCtx::Paxos(PaxosToken::Append { group, ballot, round, start_slot }),
            );
        }
        // Sole survivor: no doorbells will ever arrive, and none are
        // needed — the leader's local append is the whole majority.
        if pumped {
            while let Some((start, ops)) = self.shards[s].leader_sm.commit_if_solo() {
                self.commit_batch(core, ctx, mb, s, start, ops);
            }
        }
    }

    fn commit_batch(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize, start_slot: u64, ops: Vec<OpCall>) {
        let now = ctx.q.now();
        if now > core.busy_until {
            core.busy_total += now - core.busy_until;
            core.busy_until = now;
        }
        if let Some(t0) = self.round_start.remove(&(s, start_slot)) {
            ctx.metrics.smr_round.record(now.saturating_sub(t0));
        }
        ctx.metrics.smr_commits += ops.len() as u64;
        if self.chaos {
            for o in &ops {
                self.done_fwd.insert((o.origin, o.seq), true);
            }
        }
        for i in 0..ops.len() as u64 {
            if let Some(req) = self.shards[s].requesters.remove(&(start_slot + i)) {
                self.answer_requester(core, ctx, req, true);
            }
        }
        self.try_fan_out(core, ctx, mb, s);
    }

    fn answer_requester(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, req: Requester, committed: bool) {
        match req {
            Requester::Local { client, arrival } => {
                let t = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                core.complete_client(ctx, client, arrival, t);
            }
            Requester::Remote { reply_to, request_id } => {
                self.reply_remote(core, ctx, reply_to, request_id, true, committed);
            }
        }
    }

    fn reply_remote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, reply_to: NodeId, request_id: u64, handled: bool, committed: bool) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderReply { request_id, handled, committed },
            tok,
        );
        ctx.metrics.verbs += 1;
        let now = ctx.q.now().max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, reply_to, verb, false);
    }

    /// Forward a conflicting op from this (non-leader) replica to the
    /// leader; same retry protocol as the Mu/Raft strong path.
    fn forward_to_leader(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, op: OpCall, req: Requester) {
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        if let Requester::Local { client, arrival } = req {
            self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op });
            if self.chaos {
                core.arm_forward_watchdog(ctx, request_id);
            }
        }
        let leader = core.leader_for_op(&op);
        let tok = core.token(TokenCtx::Paxos(PaxosToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let start = ctx.q.now().max(core.busy_until);
        let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, start, core.id, leader, verb, true);
        core.busy_total += out.initiator_free_at - start;
        core.busy_until = out.initiator_free_at;
    }

    fn retry_forward(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, mut p: PendingClient) {
        p.retries += 1;
        if p.retries > 8 {
            core.note_rejected(&p.op);
            let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, p.client, p.arrival, done);
            return;
        }
        // Sharded placements route the retry by the op's group (the
        // failure plane keeps `group_leaders` current); single placement
        // refreshes the smallest-live-ID view.
        let leader = if core.placement.is_sharded() {
            core.leader_for_op(&p.op)
        } else {
            let l = mb.elect_leader();
            core.leader = l;
            l
        };
        let op = p.op;
        if leader == core.id {
            self.leader_submit(core, ctx, mb, op, Requester::Local { client: p.client, arrival: p.arrival });
            return;
        }
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, p);
        if self.chaos {
            core.arm_forward_watchdog(ctx, request_id);
        }
        let tok = core.token(TokenCtx::Paxos(PaxosToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let at = (ctx.q.now() + core.heartbeat_period_ns).max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, at, core.id, leader, verb, true);
    }

    /// Promoted or recovering peers get the leader's log for shard `s` as
    /// one exact mirror write (empty log replays too — it truncates stale
    /// tails).
    fn replay_log_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, peer: NodeId) {
        let ops: Vec<OpCall> =
            self.shards[s].log.entries_from(0).into_iter().map(|(_, e)| e.op).collect();
        let ballot = self.shards[s].leader_sm.ballot;
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::PaxosReplay { group: s as u8, ballot, ops: ops.into() },
            tok,
        )
        .on_leader_qp();
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
    }

    /// Apply this replica's own log tail (a follower promoted to leader
    /// must execute everything it accepted before serving in total order).
    fn drain_own_log(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize) {
        let entries = self.shards[s].log.drain_unapplied();
        if entries.is_empty() {
            return;
        }
        let per = core.exec().op_exec_ns + core.sys.mem.local_read_ns(core.landing_mem());
        core.occupy_batch(ctx.q.now(), per, entries.len());
        for e in entries {
            core.executions += 1;
            core.plane.apply_forced(&e.op);
        }
    }
}

impl ReplicationPath for PaxosPath {
    fn boot(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _base: u64) {
        // Followers are passive landing regions: no pollers. Visibility of
        // conflicting state at followers is commit-gated (quiescence drain
        // or promotion), so there is nothing to arm.
    }

    fn refresh_cost(&mut self, _core: &mut ReplicaCore) -> u64 {
        // The landing-region head is a register read in fabric logic; the
        // strong log is not speculatively folded into follower state (see
        // module docs), so queries pay nothing here.
        0
    }

    fn handle_client(
        &mut self,
        _core: &mut ReplicaCore,
        _ctx: &mut Ctx,
        _mb: &dyn Membership,
        _client: usize,
        _item: WorkItem,
        _arrival: Time,
    ) -> bool {
        false
    }

    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission) {
        core.occupy(sub.arrival, sub.cost);
        let req = Requester::Local { client: sub.client, arrival: sub.arrival };
        if core.leads_op(&sub.op) {
            self.leader_submit(core, ctx, mb, sub.op, req);
        } else {
            self.forward_to_leader(core, ctx, sub.op, req);
        }
    }

    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, src: NodeId, verb: Verb) {
        match verb.payload {
            Payload::PaxosAppend { group, ballot, start_slot, ops } => {
                // One-sided landing: no follower compute on the fast path.
                let s = self.sidx(group as usize);
                let shard = &mut self.shards[s];
                if !shard.acceptor.accept(ballot) {
                    return; // stale-ballot leader (also fenced at the QP)
                }
                // A batch landing beyond our append point means an earlier
                // landing-region write never arrived (fenced pre-switch or
                // eaten by fault injection): pull a replay from the sender.
                if start_slot > shard.log.next_free_slot() {
                    core.request_sync(ctx, src);
                }
                for (i, &op) in ops.iter().enumerate() {
                    shard.log.write_slot(start_slot + i as u64, ballot, op);
                }
            }
            Payload::PaxosReplay { group, ballot, ops } => {
                let s = self.sidx(group as usize);
                let shard = &mut self.shards[s];
                if !shard.acceptor.accept(ballot) {
                    return;
                }
                // Exact mirror of the (new) leader's log: stale tails
                // truncate. Entries already applied locally stay applied —
                // `applied_upto` survives within the mirrored length.
                let keep_applied = shard.log.applied_upto.min(ops.len() as u64);
                let mut log = ReplicationLog::new();
                for (slot, &op) in ops.iter().enumerate() {
                    log.write_slot(slot as u64, ballot, op);
                }
                log.applied_upto = keep_applied;
                shard.log = log;
            }
            Payload::LeaderForward { op, reply_to, request_id } => {
                if core.leads_op(&op) {
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    // Chaos-mode exactly-once: a duplicate of an op we
                    // already ordered answers with the recorded verdict.
                    if self.chaos {
                        if let Some(&committed) = self.done_fwd.get(&(op.origin, op.seq)) {
                            self.reply_remote(core, ctx, reply_to, request_id, true, committed);
                            return;
                        }
                    }
                    self.leader_submit(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                } else {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
            }
            Payload::LeaderReply { request_id, handled, committed } => {
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    if handled {
                        if !committed {
                            core.note_rejected(&p.op);
                        }
                        let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                        core.complete_client(ctx, p.client, p.arrival, done);
                    } else {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
            Payload::SyncRequest { from } => {
                // A follower completed its permission switch toward us and
                // wants the committed log (an exact ballot-gated mirror;
                // idempotent when it is already current). Sharded
                // placements mirror only the shards this replica leads.
                if core.leads_any() {
                    for s in 0..self.shards.len() {
                        if core.is_leader_of(s) {
                            self.replay_log_to(core, ctx, s, from);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn on_completion(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, ok: bool) {
        let TokenCtx::Paxos(token) = token else { return };
        match token {
            PaxosToken::Append { group, ballot, round, start_slot } => {
                let s = self.sidx(group as usize);
                if !core.is_leader_of(s) {
                    return; // deposed mid-round; takeover handles the rest
                }
                match self.shards[s].leader_sm.on_completion(ballot, round, start_slot, ok) {
                    PaxosStep::Wait => {}
                    PaxosStep::Commit { start_slot, ops } => {
                        self.commit_batch(core, ctx, mb, s, start_slot, ops);
                        // Later flights whose quorum landed first release
                        // now, in slot order.
                        while let Some((start, ops)) = self.shards[s].leader_sm.pop_released() {
                            self.commit_batch(core, ctx, mb, s, start, ops);
                        }
                    }
                    PaxosStep::Stall => {
                        // The whole window resets as a unit: committed-but-
                        // unreleased flights never applied and re-fly too.
                        self.shards[s].leader_sm.reset_window();
                        // Retry once the heartbeat scanner refreshes the
                        // live set (same recovery cadence as Mu).
                        ctx.q.push(
                            ctx.q.now() + core.heartbeat_period_ns,
                            core.id,
                            EventKind::Timer(TimerKind::SmrTick(s as u8)),
                        );
                    }
                }
            }
            PaxosToken::Forward { request_id } => {
                if !ok {
                    if let Some(p) = self.pending_fwd.remove(&request_id) {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
            PaxosToken::Lease { group, wave } => {
                // A doorbell on a lease probe is a vote: the follower's
                // permission switch accepted this leadership. NACKs need no
                // action — the campaign-retry chain re-probes.
                let s = self.sidx(group as usize);
                if self.shards[s].lease
                    || wave != self.shards[s].lease_wave
                    || !core.is_leader_of(s)
                {
                    return;
                }
                if ok {
                    self.shards[s].lease_votes += 1;
                    if self.shards[s].lease_votes as usize >= mb.live_set().len() / 2 {
                        self.paxos_grant_lease(core, ctx, mb, s);
                    }
                }
            }
        }
    }

    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind) {
        match t {
            TimerKind::SmrTick(g) => {
                let s = self.sidx(g as usize);
                if !self.shards[s].lease {
                    // Still campaigning: abdicate if the rightful leader is
                    // someone else (we were a partition-minority imposter —
                    // under sharding the placement table names the per-group
                    // rightful leader; single placement uses the smallest
                    // live ID), else re-probe. The check runs even when the
                    // table no longer names us: a heal-time realign may
                    // have re-pointed the group while our campaign was out.
                    let rightful = if core.placement.is_sharded() {
                        core.leader_of(s)
                    } else {
                        mb.elect_leader()
                    };
                    if rightful != core.id {
                        self.paxos_abdicate(core, ctx, s, rightful);
                    } else {
                        self.paxos_campaign(core, ctx, mb, s, false);
                    }
                } else if core.is_leader_of(s) {
                    self.shards[s].leader_sm.set_cluster_size(mb.live_set().len());
                    self.try_fan_out(core, ctx, mb, s);
                }
            }
            TimerKind::ForwardCheck { request_id } => {
                // Chaos-mode watchdog: the reply was lost on a faulty
                // link — re-forward (at-least-once; the leader re-checks
                // permissibility in total-order position).
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
            _ => {}
        }
    }

    fn on_membership(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, ev: MembershipEvent) {
        match ev {
            MembershipEvent::PeerFailed { peer: _ } => {
                for s in 0..self.shards.len() {
                    if core.is_leader_of(s) {
                        self.shards[s].leader_sm.set_cluster_size(mb.live_set().len());
                    }
                }
            }
            MembershipEvent::PeerRecovered { peer } => {
                for s in 0..self.shards.len() {
                    if core.is_leader_of(s) {
                        self.replay_log_to(core, ctx, s, peer);
                        self.shards[s].leader_sm.set_cluster_size(mb.live_set().len());
                    }
                }
            }
            MembershipEvent::LeaderSwitched => {
                if core.is_leader() {
                    // Takeover: outbid every ballot seen, then campaign for
                    // the lease — the completion-tracked mirror of our log
                    // to every live peer (the one-sided analogue of Mu's
                    // Prepare, which also truncates minority-written
                    // uncommitted tails). Executing our accepted tail and
                    // serving wait for the doorbell majority. This event
                    // only fires under placement = single (shard 0 is the
                    // whole pipeline).
                    ctx.metrics.elections += 1;
                    ctx.metrics.election_times.push(ctx.q.now());
                    let promised = self.shards[0].acceptor.promised;
                    self.shards[0].leader_sm.reset_window();
                    self.shards[0].leader_sm.assume_leadership(core.id, promised);
                    self.shards[0].lease = false;
                    self.paxos_campaign(core, ctx, mb, 0, true);
                }
                // Any of our forwards pending at the dead leader: retry.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
            MembershipEvent::GroupLeadersChanged => {
                // Sharded placements only: the failure plane re-placed the
                // dead node's groups. Each shard this replica just gained
                // runs the same takeover a LeaderSwitched would — outbid,
                // campaign, serve once the doorbell majority confirms.
                let mut gained = false;
                for g in 0..self.led.len() {
                    let mine = core.is_leader_of(g);
                    let was = self.led[g];
                    self.led[g] = mine;
                    let s = self.sidx(g);
                    if mine {
                        self.shards[s].leader_sm.set_cluster_size(mb.live_set().len());
                    }
                    if !mine || was {
                        continue;
                    }
                    gained = true;
                    let promised = self.shards[s].acceptor.promised;
                    self.shards[s].leader_sm.reset_window();
                    self.shards[s].leader_sm.assume_leadership(core.id, promised);
                    self.shards[s].lease = false;
                    self.paxos_campaign(core, ctx, mb, s, true);
                }
                if gained {
                    // One election per replica gaining ≥1 group — the
                    // takeover campaigns run concurrently from the same
                    // detection.
                    ctx.metrics.elections += 1;
                    ctx.metrics.election_times.push(ctx.q.now());
                }
                // Forwards pending at the dead (or re-placed) leader: the
                // per-op group routing re-resolves against the new table.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
        }
    }

    fn replay_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, peer: NodeId) {
        // Heal-time anti-entropy: mirror the committed log onto the peer a
        // partition may have starved (ballot-gated, exact overwrite —
        // idempotent when the peer is already current). Sharded placements
        // mirror only the shards this replica leads.
        let single = self.shards.len() == 1;
        for s in 0..self.shards.len() {
            if single || core.is_leader_of(s) {
                self.replay_log_to(core, ctx, s, peer);
            }
        }
    }

    fn abdicate_if_unconfirmed(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, rightful: NodeId) {
        if core.placement.is_sharded() {
            // Per-shard: a campaign that never confirmed (lease still
            // unearned) hands its group to the placement-table rightful
            // leader — the realigned table was installed before this nudge.
            for s in 0..self.shards.len() {
                if !self.shards[s].lease {
                    let r = core.leader_of(s);
                    if r != core.id {
                        self.paxos_abdicate(core, ctx, s, r);
                    }
                }
            }
            return;
        }
        if core.is_leader() && !self.shards[0].lease {
            self.paxos_abdicate(core, ctx, 0, rightful);
        }
    }

    fn flush_pending(&mut self, plane: &mut Catalog) {
        for shard in &mut self.shards {
            for e in shard.log.drain_unapplied() {
                plane.apply_forced(&e.op);
            }
        }
    }

    fn snapshot_logs(&self) -> Vec<ReplicationLog> {
        self.shards.iter().map(|s| s.log.clone()).collect()
    }

    fn install_logs(&mut self, logs: Vec<ReplicationLog>) {
        let mut logs = logs.into_iter();
        for shard in &mut self.shards {
            shard.log = logs.next().unwrap_or_default();
            // Pipeline state died with the crash; requesters' client slots
            // were reset by the failure plane.
            shard.leader_sm.clear();
            shard.requesters = FastMap::default();
            shard.lease = true;
            shard.parked.clear();
        }
        self.pending_fwd = FastMap::default();
        self.round_start = FastMap::default();
        // A freshly recovered replica leads nothing until the placement
        // table reassigns groups to it (sticky rebalance).
        self.led.iter_mut().for_each(|l| *l = false);
    }

    fn debug_status(&self) -> String {
        let q: usize = self.shards.iter().map(|s| s.leader_sm.queue_len()).sum();
        let in_flight: usize = self.shards.iter().filter(|s| s.leader_sm.in_flight()).count();
        let requesters: usize = self.shards.iter().map(|s| s.requesters.len()).sum();
        let parked: usize = self.shards.iter().map(|s| s.parked.len()).sum();
        let unleased: usize = self.shards.iter().filter(|s| !s.lease).count();
        let log_len: u64 = self.shards.iter().map(|s| s.log.len()).sum();
        let applied: u64 = self.shards.iter().map(|s| s.log.applied_upto).sum();
        format!(
            "paxos shards={} ballot={} q={} in_flight={} pending_fwd={} requesters={} log_len={} applied={} batch={} unleased={} parked={}",
            self.shards.len(),
            self.shards[0].leader_sm.ballot,
            q,
            in_flight,
            self.pending_fwd.len(),
            requesters,
            log_len,
            applied,
            self.batch,
            unleased,
            parked
        )
    }
}
