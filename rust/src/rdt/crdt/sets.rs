//! Set CRDTs (Table A.1): G-Set (reducible insert), PN-Set and 2P-Set
//! (irreducible insert/remove — order within an origin matters, so they use
//! the per-origin FIFO queue path of §4.2).

use std::collections::{HashMap, HashSet};

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_INSERT: u8 = 0;
pub const OP_REMOVE: u8 = 1;

/// Element universe used by workload generators (small enough that inserts
/// and removes actually collide, exercising merge semantics).
pub const ELEMENT_UNIVERSE: u64 = 4096;

/// Grow-only set: insert only; reducible (a batch of inserts summarizes to
/// a set union).
#[derive(Clone, Debug, Default)]
pub struct GSet {
    s: HashSet<u64>,
}

impl GSet {
    pub fn contains(&self, e: u64) -> bool {
        self.s.contains(&e)
    }

    pub fn len(&self) -> usize {
        self.s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.s.is_empty()
    }
}

impl Rdt for GSet {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::GSet
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Reducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        op.is_query() || op.opcode == OP_INSERT
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        debug_assert_eq!(op.opcode, OP_INSERT);
        self.s.insert(op.a)
    }

    fn query(&self) -> QueryValue {
        QueryValue::Size(self.s.len())
    }

    fn state_digest(&self) -> u64 {
        self.s.iter().fold(0, |acc, &e| acc ^ mix64(e))
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        OpCall::new(OP_INSERT, rng.gen_range(ELEMENT_UNIVERSE), 0, 0.0)
    }
}

/// PN-Set: per-element counter; insert increments, remove decrements,
/// present iff counter > 0 (appendix A.1). Irreducible: an origin's
/// insert/remove sequence must apply in order.
#[derive(Clone, Debug, Default)]
pub struct PnSet {
    c: HashMap<u64, i64>,
}

impl PnSet {
    pub fn contains(&self, e: u64) -> bool {
        self.c.get(&e).copied().unwrap_or(0) > 0
    }

    pub fn present_count(&self) -> usize {
        self.c.values().filter(|&&v| v > 0).count()
    }
}

impl Rdt for PnSet {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::PnSet
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Irreducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        op.is_query() || matches!(op.opcode, OP_INSERT | OP_REMOVE)
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        let e = self.c.entry(op.a).or_insert(0);
        match op.opcode {
            OP_INSERT => *e += 1,
            OP_REMOVE => *e -= 1,
            _ => unreachable!("pn-set opcode {}", op.opcode),
        }
        true
    }

    fn query(&self) -> QueryValue {
        QueryValue::Size(self.present_count())
    }

    fn state_digest(&self) -> u64 {
        self.c
            .iter()
            .filter(|(_, &v)| v != 0)
            .fold(0, |acc, (&e, &v)| acc ^ mix64(e).wrapping_mul(mix64(v as u64) | 1))
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        let opcode = if rng.gen_bool(0.6) { OP_INSERT } else { OP_REMOVE };
        OpCall::new(opcode, rng.gen_range(ELEMENT_UNIVERSE), 0, 0.0)
    }
}

/// 2P-Set: two G-Sets (added, removed); once removed an element can never
/// be reinserted (appendix A.1).
#[derive(Clone, Debug, Default)]
pub struct TwoPSet {
    added: HashSet<u64>,
    removed: HashSet<u64>,
}

impl TwoPSet {
    pub fn contains(&self, e: u64) -> bool {
        self.added.contains(&e) && !self.removed.contains(&e)
    }

    pub fn present_count(&self) -> usize {
        self.added.iter().filter(|e| !self.removed.contains(e)).count()
    }
}

impl Rdt for TwoPSet {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::TwoPSet
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Irreducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            OP_INSERT => !self.removed.contains(&op.a),
            OP_REMOVE => true,
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_INSERT => {
                // Insert always lands in `added` (state convergence); the
                // tombstone in `removed` masks it from lookups (2P rule).
                self.added.insert(op.a)
            }
            OP_REMOVE => {
                // remove is recorded even if not yet added at this replica —
                // it tombstones any concurrent insert.
                self.removed.insert(op.a)
            }
            _ => unreachable!("2p-set opcode {}", op.opcode),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Size(self.present_count())
    }

    fn state_digest(&self) -> u64 {
        let da = self.added.iter().fold(0u64, |acc, &e| acc ^ mix64(e));
        let dr = self.removed.iter().fold(0u64, |acc, &e| acc ^ mix64(e | 1 << 63));
        da ^ dr.rotate_left(13)
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        let opcode = if rng.gen_bool(0.7) { OP_INSERT } else { OP_REMOVE };
        OpCall::new(opcode, rng.gen_range(ELEMENT_UNIVERSE), 0, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(opcode: u8, e: u64) -> OpCall {
        OpCall::new(opcode, e, 0, 0.0)
    }

    #[test]
    fn gset_grows_only() {
        let mut s = GSet::default();
        assert!(s.apply(&op(OP_INSERT, 1)));
        assert!(!s.apply(&op(OP_INSERT, 1)), "re-insert is a no-op");
        assert!(s.contains(1));
        assert_eq!(s.query(), QueryValue::Size(1));
    }

    #[test]
    fn gset_digest_order_free() {
        let mut a = GSet::default();
        let mut b = GSet::default();
        for e in [5u64, 9, 2] {
            a.apply(&op(OP_INSERT, e));
        }
        for e in [2u64, 5, 9] {
            b.apply(&op(OP_INSERT, e));
        }
        assert_eq!(a.state_digest(), b.state_digest());
    }

    #[test]
    fn pnset_counter_semantics() {
        let mut s = PnSet::default();
        s.apply(&op(OP_INSERT, 7));
        s.apply(&op(OP_INSERT, 7));
        s.apply(&op(OP_REMOVE, 7));
        assert!(s.contains(7), "counter 1 > 0");
        s.apply(&op(OP_REMOVE, 7));
        assert!(!s.contains(7));
        s.apply(&op(OP_REMOVE, 7)); // negative counter
        s.apply(&op(OP_INSERT, 7));
        assert!(!s.contains(7), "negative counters need multiple inserts");
    }

    #[test]
    fn pnset_commutes() {
        let ops = [op(OP_INSERT, 1), op(OP_REMOVE, 1), op(OP_INSERT, 2), op(OP_INSERT, 1)];
        let mut a = PnSet::default();
        let mut b = PnSet::default();
        for o in &ops {
            a.apply(o);
        }
        for o in ops.iter().rev() {
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
    }

    #[test]
    fn two_p_set_no_reinsert() {
        let mut s = TwoPSet::default();
        s.apply(&op(OP_INSERT, 3));
        s.apply(&op(OP_REMOVE, 3));
        assert!(!s.contains(3));
        assert!(!s.permissible(&op(OP_INSERT, 3)), "reinsert impermissible");
        s.apply(&op(OP_INSERT, 3));
        assert!(!s.contains(3), "tombstone wins");
    }

    #[test]
    fn two_p_set_remove_insert_commute() {
        // remove arrives before insert at replica b: final states converge.
        let ins = op(OP_INSERT, 4);
        let rem = op(OP_REMOVE, 4);
        let mut a = TwoPSet::default();
        a.apply(&ins);
        a.apply(&rem);
        let mut b = TwoPSet::default();
        b.apply(&rem);
        b.apply(&ins);
        assert_eq!(a.state_digest(), b.state_digest());
        assert!(!a.contains(4) && !b.contains(4));
    }
}
