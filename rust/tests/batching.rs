//! Per-path batching determinism: coalescing queued submissions into one
//! wire verb may re-time propagation, but it must never change outcomes.
//!
//! The property is asserted where it is *constructible*: commutative CRDT
//! workloads (sums/unions are order-free and rejection-free, so the final
//! state is a pure function of the issued op multiset, which is seed-fixed
//! regardless of timing). The conflicting-path analogue lives in
//! `backend_equivalence.rs` (`batched_runs_reproduce_unbatched_digests_*`)
//! on a rejection-proof Account workload. A latency-monotonicity sanity
//! check rides along on every emitted histogram: quantiles must be
//! monotone (p50 <= p99 <= max), batched or not.

use safardb::config::{SimConfig, WorkloadKind};
use safardb::engine::cluster::{self, RunReport};
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

fn latency_monotone(rep: &RunReport) -> bool {
    let h = &rep.metrics.response;
    h.p50() <= h.p99() && h.p99() <= h.max()
}

#[test]
fn prop_batching_changes_timing_never_outcomes() {
    prop::check("batching-determinism", 0xba7c4, 10, |rng| {
        let rdt =
            *rng.choose(&[RdtKind::PnCounter, RdtKind::GSet, RdtKind::PnSet, RdtKind::TwoPSet]);
        let seed = rng.next_u64();
        let n = 3 + rng.gen_range(5) as usize;
        let update_pct = 20 + rng.gen_range(40) as u8;
        let run_at = |batch: u32| {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
            cfg.n_replicas = n;
            cfg.update_pct = update_pct;
            cfg.total_ops = 6_000;
            cfg.seed = seed;
            cfg.batch_size = batch;
            let rep = cluster::run(cfg);
            assert!(
                rep.converged() && rep.invariants_ok,
                "{} n={n} u={update_pct} batch={batch}: basic guarantees broke",
                rdt.name()
            );
            rep
        };
        let base = run_at(1);
        prop_assert!(
            base.metrics.coalesced == 0,
            "batch_size=1 must never emit batch verbs (coalesced={})",
            base.metrics.coalesced
        );
        prop_assert!(latency_monotone(&base), "unbatched histogram quantiles not monotone");
        for batch in [4u32, 16] {
            let rep = run_at(batch);
            prop_assert!(
                rep.digests[0] == base.digests[0],
                "{} n={n} u={update_pct} batch={batch}: batching changed the converged \
                 state ({:#x} vs {:#x})",
                rdt.name(),
                rep.digests[0],
                base.digests[0]
            );
            prop_assert!(
                rep.metrics.total_completed() == base.metrics.total_completed(),
                "{} batch={batch}: client completions diverged",
                rdt.name()
            );
            prop_assert!(
                latency_monotone(&rep),
                "{} batch={batch}: histogram quantiles not monotone",
                rdt.name()
            );
        }
        Ok(())
    });
}

#[test]
fn coalescer_engages_under_pressure_and_only_when_enabled() {
    // 8 closed-loop slots at 100% reducible updates submit several ops per
    // poll interval, so the coalescer must merge; with batch_size=1 the
    // batch payloads must never appear.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
    cfg.n_replicas = 4;
    cfg.update_pct = 100;
    cfg.clients_per_replica = 8;
    cfg.total_ops = 8_000;
    cfg.batch_size = 8;
    let batched = cluster::run(cfg.clone());
    assert!(batched.converged() && batched.invariants_ok);
    assert!(
        batched.metrics.coalesced > 0,
        "no merges despite 100% updates over 8 slots per replica"
    );
    assert!(latency_monotone(&batched));

    cfg.batch_size = 1;
    let unbatched = cluster::run(cfg);
    assert_eq!(unbatched.metrics.coalesced, 0, "unbatched run emitted batch verbs");
    assert_eq!(
        unbatched.digests[0], batched.digests[0],
        "coalescing changed the converged counter state"
    );
    assert_eq!(unbatched.metrics.total_completed(), batched.metrics.total_completed());
}

#[test]
fn irreducible_fifo_survives_batching() {
    // PN-Set correctness depends on per-origin insert/remove order; the
    // QueueBatch payload must preserve FIFO inside and across chunks.
    for batch in [1u32, 4, 16] {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnSet));
        cfg.n_replicas = 5;
        cfg.update_pct = 60;
        cfg.total_ops = 8_000;
        cfg.seed = 0xF1F0;
        cfg.batch_size = batch;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "batch={batch}: diverged {:?}", rep.digests);
        assert!(rep.invariants_ok, "batch={batch}");
    }
}
