//! Fig 6: reducible-transaction implementations (§4.1) on PN-Counter
//! (CRDT) and Account (WRDT) — RDMA Write (no buffer) vs Write (buffered)
//! vs RDMA RPC; 3–8 nodes, 15/20/25 % updates.
//!
//! Expected shape: buffering/RPC ≈8× better RT for the counter (queries
//! stop folding HBM); for Account, RPC beats buffering (the leader's memory
//! accesses cannot be fully hidden by polling).

use crate::config::{PropagationMode, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

const CONFIGS: &[(&str, PropagationMode)] = &[
    ("write-nobuf", PropagationMode::WriteNoBuffer),
    ("write-buffered", PropagationMode::WriteBuffered),
    ("rpc", PropagationMode::Rpc),
];

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for rdt in [RdtKind::PnCounter, RdtKind::Account] {
        let mut t = Table::new(
            &format!("Fig 6 — reducible configs on {}", rdt.name()),
            &["config", "nodes", "upd%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for &(name, mode) in CONFIGS {
            for &n in nodes(quick) {
                for &u in UPDATE_SWEEP {
                    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
                    cfg.prop_reducible = mode;
                    // Conflicting path held at the paper's baseline here so
                    // the reducible axis is isolated.
                    cfg.prop_conflicting = PropagationMode::WriteNoBuffer;
                    cfg.n_replicas = n;
                    cfg.update_pct = u;
                    jobs.push(((name, n, u), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((name, n, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                name.into(),
                n.to_string(),
                u.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::geomean_ratio;

    #[test]
    fn buffering_and_rpc_beat_nobuffer_on_counter() {
        let t = &run(true)[0];
        let series = |cfg: &str| -> Vec<f64> {
            t.rows()
                .iter()
                .filter(|r| r[0] == cfg)
                .map(|r| r[3].parse().unwrap())
                .collect()
        };
        let nobuf = series("write-nobuf");
        let buf = series("write-buffered");
        let rpc = series("rpc");
        let gain_buf = geomean_ratio(&nobuf, &buf);
        let gain_rpc = geomean_ratio(&nobuf, &rpc);
        // Paper: ~8x lower response time. Our client-ingress overhead
        // compresses the ratio (EXPERIMENTS.md discusses the delta); the
        // *ordering* — nobuffer strictly worst — must hold clearly.
        assert!(gain_buf > 1.4, "buffered gain {gain_buf}");
        assert!(gain_rpc > 1.4, "rpc gain {gain_rpc}");
        assert!(gain_rpc >= gain_buf * 0.8, "rpc at least comparable to buffered");
    }
}
