//! Typed batch accelerators over the kernel runtime — the Rust-side mirror
//! of the paper's FPGA-resident operators (Fig 1's Dispatcher targets),
//! with padding to the fixed AOT export shapes (N=8 replicas, K=1024 keys,
//! B=256 burst, W=512 words — python/compile/model.py).
//!
//! Every operator has a scalar fallback in `rdt/` / `engine/store.rs`; the
//! integration tests assert kernel == scalar exactly.

use super::error::{Error, Result};
use super::exec::{Literal, Runtime};

// Export shape constants live with the builtin signatures so padding and
// type-checking can never drift apart.
pub use super::artifacts::{B_BURST, K_KEYS, N_REPLICAS, W_WORDS};

fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<()> {
    if cond {
        Ok(())
    } else {
        Err(Error::msg(msg()))
    }
}

pub struct Accelerator {
    rt: Runtime,
}

impl Accelerator {
    pub fn new(rt: Runtime) -> Self {
        Accelerator { rt }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Accelerator { rt: Runtime::load(super::DEFAULT_ARTIFACTS)? })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn calls(&self) -> u64 {
        self.rt.calls
    }

    fn pad_rows_f32(rows: &[Vec<f32>], k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; N_REPLICAS * k];
        for (i, row) in rows.iter().enumerate() {
            out[i * k..i * k + row.len()].copy_from_slice(row);
        }
        out
    }

    fn pad_rows_i32(rows: &[Vec<i32>], k: usize) -> Vec<i32> {
        let mut out = vec![0i32; N_REPLICAS * k];
        for (i, row) in rows.iter().enumerate() {
            out[i * k..i * k + row.len()].copy_from_slice(row);
        }
        out
    }

    /// PN-Counter fold: per-replica increment/decrement contribution rows
    /// -> merged values (first `k` entries meaningful).
    pub fn pn_counter_merge(&mut self, p: &[Vec<f32>], m: &[Vec<f32>]) -> Result<Vec<f32>> {
        ensure(p.len() <= N_REPLICAS && p.len() == m.len(), || {
            format!("pn_counter_merge: <={N_REPLICAS} replica rows, matching p/m")
        })?;
        let k = p.iter().map(|r| r.len()).max().unwrap_or(0);
        ensure(k <= K_KEYS, || format!("pn_counter_merge: <={K_KEYS} counters per tile"))?;
        let pl = Runtime::lit_f32_2d(&Self::pad_rows_f32(p, K_KEYS), N_REPLICAS, K_KEYS)?;
        let ml = Runtime::lit_f32_2d(&Self::pad_rows_f32(m, K_KEYS), N_REPLICAS, K_KEYS)?;
        let outs = self.rt.call("pn_counter_merge", &[pl, ml])?;
        let mut v = outs[0].f32s()?.to_vec();
        v.truncate(k);
        Ok(v)
    }

    /// LWW fold: (values, timestamps) per replica -> merged (values, ts).
    pub fn lww_merge(
        &mut self,
        vals: &[Vec<f32>],
        ts: &[Vec<i32>],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        ensure(vals.len() <= N_REPLICAS && vals.len() == ts.len(), || {
            "lww_merge: row count".to_string()
        })?;
        let k = vals.iter().map(|r| r.len()).max().unwrap_or(0);
        ensure(k <= K_KEYS, || format!("lww_merge: <={K_KEYS} registers per tile"))?;
        let vl = Runtime::lit_f32_2d(&Self::pad_rows_f32(vals, K_KEYS), N_REPLICAS, K_KEYS)?;
        let tl = Runtime::lit_i32_2d(&Self::pad_rows_i32(ts, K_KEYS), N_REPLICAS, K_KEYS)?;
        let outs = self.rt.call("lww_register_merge", &[vl, tl])?;
        let mut v = outs[0].f32s()?.to_vec();
        let mut t = outs[1].i32s()?.to_vec();
        v.truncate(k);
        t.truncate(k);
        Ok((v, t))
    }

    /// G-Set fold: per-replica bitmaps -> merged bitmap.
    pub fn gset_merge(&mut self, bitmaps: &[Vec<i32>]) -> Result<Vec<i32>> {
        ensure(bitmaps.len() <= N_REPLICAS, || {
            format!("gset_merge: <={N_REPLICAS} replica rows")
        })?;
        let w = bitmaps.iter().map(|r| r.len()).max().unwrap_or(0);
        ensure(w <= W_WORDS, || format!("gset_merge: <={W_WORDS} bitmap words"))?;
        let bl = Runtime::lit_i32_2d(&Self::pad_rows_i32(bitmaps, W_WORDS), N_REPLICAS, W_WORDS)?;
        let outs = self.rt.call("gset_merge", &[bl])?;
        let mut v = outs[0].i32s()?.to_vec();
        v.truncate(w);
        Ok(v)
    }

    /// 2P-Set fold: present = OR(adds) & !OR(removes).
    pub fn two_p_set_merge(
        &mut self,
        adds: &[Vec<i32>],
        removes: &[Vec<i32>],
    ) -> Result<Vec<i32>> {
        ensure(adds.len() <= N_REPLICAS && removes.len() <= N_REPLICAS, || {
            "two_p_set_merge: row count".to_string()
        })?;
        let w = adds
            .iter()
            .chain(removes.iter())
            .map(|r| r.len())
            .max()
            .unwrap_or(0);
        ensure(w <= W_WORDS, || format!("two_p_set_merge: <={W_WORDS} bitmap words"))?;
        let al = Runtime::lit_i32_2d(&Self::pad_rows_i32(adds, W_WORDS), N_REPLICAS, W_WORDS)?;
        let rl = Runtime::lit_i32_2d(&Self::pad_rows_i32(removes, W_WORDS), N_REPLICAS, W_WORDS)?;
        let outs = self.rt.call("two_p_set_merge", &[al, rl])?;
        let mut v = outs[0].i32s()?.to_vec();
        v.truncate(w);
        Ok(v)
    }

    /// Account overdraft scan: (starting balance, signed deltas) ->
    /// (accept mask, final balance). Padding deltas are 0 (always accepted,
    /// no effect).
    pub fn account_guard(&mut self, b0: f32, deltas: &[f32]) -> Result<(Vec<bool>, f32)> {
        ensure(deltas.len() <= B_BURST, || format!("account_guard: <={B_BURST} ops per burst"))?;
        let mut d = deltas.to_vec();
        d.resize(B_BURST, 0.0);
        let outs = self
            .rt
            .call("account_guard", &[Runtime::lit_f32_1d(&[b0]), Runtime::lit_f32_1d(&d)])?;
        let mask = outs[0].i32s()?;
        let bal = outs[1].f32s()?[0];
        Ok((mask[..deltas.len()].iter().map(|&m| m != 0).collect(), bal))
    }

    /// KV burst scatter-add (duplicate keys accumulate). State tile must be
    /// <= K_KEYS; padding ops target key 0 with delta 0.
    pub fn kv_burst_apply(
        &mut self,
        state: &[f32],
        keys: &[i32],
        deltas: &[f32],
    ) -> Result<Vec<f32>> {
        ensure(state.len() <= K_KEYS, || format!("kv_burst_apply: <={K_KEYS} keys per tile"))?;
        ensure(keys.len() == deltas.len() && keys.len() <= B_BURST, || {
            "kv_burst_apply: burst shape".to_string()
        })?;
        ensure(keys.iter().all(|&k| (k as usize) < state.len().max(1)), || {
            "kv_burst_apply: keys must be in range".to_string()
        })?;
        let mut s = state.to_vec();
        s.resize(K_KEYS, 0.0);
        let mut kk = keys.to_vec();
        kk.resize(B_BURST, 0);
        let mut dd = deltas.to_vec();
        dd.resize(B_BURST, 0.0);
        let outs = self.rt.call(
            "kv_burst_apply",
            &[Runtime::lit_f32_1d(&s), Runtime::lit_i32_1d(&kk), Runtime::lit_f32_1d(&dd)],
        )?;
        let mut v = outs[0].f32s()?.to_vec();
        v.truncate(state.len());
        Ok(v)
    }

    /// Fused SmallBank step: guard one hot account's delta batch, mask the
    /// burst, scatter-apply. Returns (new state, accept mask, final guard
    /// balance).
    pub fn smallbank_burst(
        &mut self,
        state: &[f32],
        keys: &[i32],
        deltas: &[f32],
        b0: f32,
        guard_deltas: &[f32],
    ) -> Result<(Vec<f32>, Vec<bool>, f32)> {
        ensure(state.len() <= K_KEYS && keys.len() == deltas.len(), || {
            "smallbank_burst: shapes".to_string()
        })?;
        ensure(keys.len() <= B_BURST && guard_deltas.len() <= B_BURST, || {
            "smallbank_burst: burst".to_string()
        })?;
        let mut s = state.to_vec();
        s.resize(K_KEYS, 0.0);
        let mut kk = keys.to_vec();
        kk.resize(B_BURST, 0);
        let mut dd = deltas.to_vec();
        dd.resize(B_BURST, 0.0);
        let mut gg = guard_deltas.to_vec();
        gg.resize(B_BURST, 0.0);
        let outs = self.rt.call(
            "smallbank_burst",
            &[
                Runtime::lit_f32_1d(&s),
                Runtime::lit_i32_1d(&kk),
                Runtime::lit_f32_1d(&dd),
                Runtime::lit_f32_1d(&[b0]),
                Runtime::lit_f32_1d(&gg),
            ],
        )?;
        let mut v = outs[0].f32s()?.to_vec();
        v.truncate(state.len());
        let mask = outs[1].i32s()?;
        let bal = outs[2].f32s()?[0];
        Ok((v, mask[..guard_deltas.len()].iter().map(|&m| m != 0).collect(), bal))
    }
}
