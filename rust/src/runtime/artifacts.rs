//! Artifact manifest: `artifacts/manifest.txt`, one line per exported
//! entry — `name;in=float32[8x1024],...;out=float32[1024],...` — written by
//! `python/compile/aot.py` and parsed here so the runtime can type-check
//! inputs before dispatching them to the executor.
//!
//! When the AOT artifacts are absent (JAX not installed, `make artifacts`
//! never run), [`Manifest::builtin`] supplies the same signatures from the
//! export table in `python/compile/model.py`, so the reference executor
//! stays usable everywhere.

use std::path::Path;

use super::error::{Context, Error, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::msg(format!("unsupported dtype {other}"))),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    fn parse(s: &str) -> Result<Self> {
        let (dt, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor sig {s}"))?;
        let dims = rest.trim_end_matches(']');
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .with_context(|| format!("bad dim '{d}' in tensor sig {s}"))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSig { dtype: DType::parse(dt)?, shape })
    }

    fn of(dtype: DType, shape: &[usize]) -> TensorSig {
        TensorSig { dtype, shape: shape.to_vec() }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Signature {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<Signature>,
}

/// Export shape constants (mirrors python/compile/model.py).
pub const N_REPLICAS: usize = 8;
pub const K_KEYS: usize = 1024;
pub const B_BURST: usize = 256;
pub const W_WORDS: usize = 512;

impl Manifest {
    pub fn parse(body: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (i, line) in body.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split(';');
            let name = parts.next().context("missing name")?.to_string();
            let ins = parts
                .next()
                .and_then(|p| p.strip_prefix("in="))
                .with_context(|| format!("line {}: missing in=", i + 1))?;
            let outs = parts
                .next()
                .and_then(|p| p.strip_prefix("out="))
                .with_context(|| format!("line {}: missing out=", i + 1))?;
            let parse_list = |s: &str| -> Result<Vec<TensorSig>> {
                s.split(',').map(TensorSig::parse).collect()
            };
            entries.push(Signature { name, inputs: parse_list(ins)?, outputs: parse_list(outs)? });
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let body = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Self::parse(&body)
    }

    /// The export table of python/compile/model.py, verbatim. Used when no
    /// artifacts directory exists, and to validate loaded manifests.
    pub fn builtin() -> Manifest {
        use DType::{F32, I32};
        let sig = |name: &str, inputs: Vec<TensorSig>, outputs: Vec<TensorSig>| Signature {
            name: name.to_string(),
            inputs,
            outputs,
        };
        let nk = [N_REPLICAS, K_KEYS];
        let nw = [N_REPLICAS, W_WORDS];
        Manifest {
            entries: vec![
                sig(
                    "pn_counter_merge",
                    vec![TensorSig::of(F32, &nk), TensorSig::of(F32, &nk)],
                    vec![TensorSig::of(F32, &[K_KEYS])],
                ),
                sig(
                    "lww_register_merge",
                    vec![TensorSig::of(F32, &nk), TensorSig::of(I32, &nk)],
                    vec![TensorSig::of(F32, &[K_KEYS]), TensorSig::of(I32, &[K_KEYS])],
                ),
                sig(
                    "gset_merge",
                    vec![TensorSig::of(I32, &nw)],
                    vec![TensorSig::of(I32, &[W_WORDS])],
                ),
                sig(
                    "two_p_set_merge",
                    vec![TensorSig::of(I32, &nw), TensorSig::of(I32, &nw)],
                    vec![TensorSig::of(I32, &[W_WORDS])],
                ),
                sig(
                    "account_guard",
                    vec![TensorSig::of(F32, &[1]), TensorSig::of(F32, &[B_BURST])],
                    vec![TensorSig::of(I32, &[B_BURST]), TensorSig::of(F32, &[1])],
                ),
                sig(
                    "kv_burst_apply",
                    vec![
                        TensorSig::of(F32, &[K_KEYS]),
                        TensorSig::of(I32, &[B_BURST]),
                        TensorSig::of(F32, &[B_BURST]),
                    ],
                    vec![TensorSig::of(F32, &[K_KEYS])],
                ),
                sig(
                    "smallbank_burst",
                    vec![
                        TensorSig::of(F32, &[K_KEYS]),
                        TensorSig::of(I32, &[B_BURST]),
                        TensorSig::of(F32, &[B_BURST]),
                        TensorSig::of(F32, &[1]),
                        TensorSig::of(F32, &[B_BURST]),
                    ],
                    vec![
                        TensorSig::of(F32, &[K_KEYS]),
                        TensorSig::of(I32, &[B_BURST]),
                        TensorSig::of(F32, &[1]),
                    ],
                ),
            ],
        }
    }

    pub fn get(&self, name: &str) -> Option<&Signature> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
pn_counter_merge;in=float32[8x1024],float32[8x1024];out=float32[1024]
account_guard;in=float32[1],float32[256];out=int32[256],float32[1]
";

    #[test]
    fn parses_manifest_lines() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let pn = m.get("pn_counter_merge").unwrap();
        assert_eq!(pn.inputs.len(), 2);
        assert_eq!(pn.inputs[0].shape, vec![8, 1024]);
        assert_eq!(pn.inputs[0].dtype, DType::F32);
        assert_eq!(pn.outputs[0].elems(), 1024);
        let ag = m.get("account_guard").unwrap();
        assert_eq!(ag.outputs[0].dtype, DType::I32);
        assert_eq!(ag.outputs[1].shape, vec![1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name_only").is_err());
        assert!(Manifest::parse("x;in=f99[2];out=float32[1]").is_err());
    }

    #[test]
    fn missing_entry_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn builtin_matches_model_py_exports() {
        let m = Manifest::builtin();
        assert_eq!(m.entries.len(), 7);
        let pn = m.get("pn_counter_merge").unwrap();
        assert_eq!(pn.inputs[0].shape, vec![N_REPLICAS, K_KEYS]);
        let sb = m.get("smallbank_burst").unwrap();
        assert_eq!(sb.inputs.len(), 5);
        assert_eq!(sb.outputs.len(), 3);
        // The builtin sample lines parse to the same signatures.
        let parsed = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(
            parsed.get("account_guard").unwrap().inputs,
            m.get("account_guard").unwrap().inputs
        );
    }
}
