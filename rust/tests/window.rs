//! Pipelined strong plane — the sliding-window (`SimConfig::window`)
//! equivalence and chaos suite.
//!
//! The window overlaps consensus rounds; it must never change *what*
//! commits, only *when*. The oracle mirrors the batching/placement
//! suites: on rejection-proof catalogs (no interleaving can reject, so
//! the converged state is the order-free fold of the issued ops) every
//! pipeline depth must land on byte-identical digests and commit counts
//! under every backend — and under chaos the window is a fate-sharing
//! unit: a deposed leader's uncommitted out-of-order quorums must never
//! apply.

use safardb::config::{
    CatalogSpec, ConsensusBackend, FaultSchedule, LeaderPlacement, SimConfig, WorkloadKind,
};
use safardb::engine::cluster::{self, RunReport};
use safardb::rdt::RdtKind;

fn run_checked(cfg: SimConfig, label: &str) -> RunReport {
    let rep = cluster::run(cfg);
    assert!(rep.converged(), "{label}: replicas diverged: {:?}", rep.digests);
    assert!(rep.invariants_ok, "{label}: integrity violated");
    rep
}

/// Account workload that cannot reject in *any* interleaving (12 ops ×
/// ≤80-unit withdrawals < the 1000 seed balance) — same construction as
/// the backend-equivalence suite, so the conflicting path is
/// byte-comparable across pipeline depths.
fn rejection_proof_account(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 4;
    cfg.update_pct = 100;
    cfg.total_ops = 12;
    cfg.seed = seed;
    cfg
}

/// Rejection-proof heterogeneous catalog (commutative counters/sets plus
/// under-budget accounts) — exercises multiple sync groups so per-group
/// windows run concurrently.
fn rejection_proof_mixed(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.objects = CatalogSpec::parse("counter:2,gset:1,account:2").unwrap();
    cfg.n_replicas = 4;
    cfg.update_pct = 100;
    cfg.total_ops = 12;
    cfg.seed = seed;
    cfg
}

#[test]
fn window_depths_reproduce_stop_and_wait_digests_across_backends() {
    // Out-of-order quorum collection + in-order commit must be outcome
    // invariant: any window depth reproduces the window=1 digests and
    // commit counts on both rejection-proof catalogs, per backend.
    for backend in ConsensusBackend::ALL {
        for (label, mk) in [
            ("account", rejection_proof_account as fn(u64) -> SimConfig),
            ("mixed", rejection_proof_mixed as fn(u64) -> SimConfig),
        ] {
            for seed in [0x817D_0001u64, 0x817D_0002] {
                let mut base = mk(seed);
                base.backend = backend;
                let lbl = format!("{}/{label} seed={seed:#x}", backend.name());
                let one = run_checked(base.clone(), &lbl);
                assert_eq!(one.metrics.rejected, 0, "{lbl}: workload is rejection-proof");
                for window in [4u32, 16] {
                    let mut cfg = base.clone();
                    cfg.window = window;
                    let rep = run_checked(cfg, &lbl);
                    assert!(rep.converged_per_object(), "{lbl} w={window}: per-object");
                    assert_eq!(
                        one.object_digests[0], rep.object_digests[0],
                        "{lbl} w={window}: pipelining changed outcomes"
                    );
                    assert_eq!(
                        one.metrics.smr_commits, rep.metrics.smr_commits,
                        "{lbl} w={window}: commit count diverged"
                    );
                    assert_eq!(one.metrics.rejected, rep.metrics.rejected);
                }
            }
        }
    }
}

#[test]
fn window_composes_with_batching_and_sharded_placement() {
    // The window multiplies the other strong-plane knobs rather than
    // replacing them: batch=8 × window=8 under hash placement still lands
    // on the stop-and-wait single-leader digests.
    for backend in ConsensusBackend::ALL {
        let mut base = rejection_proof_mixed(0x817D_C095);
        base.backend = backend;
        let one = run_checked(base.clone(), backend.name());
        let mut cfg = base.clone();
        cfg.batch_size = 8;
        cfg.window = 8;
        cfg.placement = LeaderPlacement::Hash;
        let rep = run_checked(cfg, backend.name());
        assert_eq!(
            one.object_digests[0],
            rep.object_digests[0],
            "{}: batch×window×placement changed outcomes",
            backend.name()
        );
        assert_eq!(one.metrics.smr_commits, rep.metrics.smr_commits, "{}", backend.name());
    }
}

#[test]
fn window_1_is_bit_identical_to_seed_behavior() {
    // window=1 is the default and must not perturb anything — digests,
    // event counts, completions all bit-equal to an explicit window=1 run
    // (the config default) on a realistic WRDT mix. Guards the default
    // path: pipelining machinery must be invisible until opted into.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 4;
    cfg.update_pct = 30;
    cfg.total_ops = 6_000;
    cfg.seed = 0x81D0_617;
    for backend in ConsensusBackend::ALL {
        cfg.backend = backend;
        let a = run_checked(cfg.clone(), backend.name());
        let mut explicit = cfg.clone();
        explicit.window = 1;
        let b = run_checked(explicit, backend.name());
        assert_eq!(a.digests, b.digests, "{}", backend.name());
        assert_eq!(a.metrics.events, b.metrics.events, "{}", backend.name());
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        // Telemetry agrees the pipeline never opened past depth 1.
        assert!(a.metrics.inflight_max_overall() <= 1, "{}", backend.name());
    }
}

#[test]
fn crdt_workloads_ignore_the_window() {
    // No conflicting ops → the strong path never runs → the window knob
    // must be invisible down to the event stream.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
    cfg.total_ops = 4_000;
    cfg.update_pct = 30;
    cfg.seed = 0x81D_C4D7;
    let one = run_checked(cfg.clone(), "w1");
    let mut deep = cfg.clone();
    deep.window = 16;
    let rep = run_checked(deep, "w16");
    assert_eq!(one.digests, rep.digests, "window perturbed a CRDT-only run");
    assert_eq!(one.metrics.events, rep.metrics.events, "window perturbed the event stream");
}

#[test]
fn leader_crash_with_full_window_converges_on_all_backends() {
    // The chaos unit test for the tentpole: crash the leader at a rate
    // that keeps its window full, so takeover replay must cover all
    // uncommitted window slots and the deposed leader's out-of-order
    // quorums must never apply. Re-election happens, no committed op is
    // lost, and the survivors converge with integrity intact.
    for backend in ConsensusBackend::ALL {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        cfg.backend = backend;
        cfg.n_replicas = 5;
        cfg.update_pct = 25;
        cfg.total_ops = 10_000;
        cfg.window = 16;
        cfg.seed = 0x81D_C4A0;
        cfg.fault = FaultSchedule::parse("crash@50:leader").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed[0], "{b}: crashed leader stays down");
        assert_ne!(rep.leader, 0, "{b}: a successor leads");
        assert!(rep.metrics.elections >= 1, "{b}: re-election happened");
        assert!(rep.converged(), "{b}: diverged with a full window: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{b}: integrity broke (uncommitted window slot applied)");
        assert!(rep.metrics.smr_commits > 0, "{b}: strong path unexercised");
    }
}

#[test]
fn partition_minority_imposter_with_inflight_window_mutates_nothing() {
    // PR-8's minority-imposter scenario with the pipeline open: a cut
    // endpoint that re-places groups onto itself now carries a *window* of
    // unconfirmed rounds, and the per-group lease fence must gate all of
    // them — none may apply. Runs under a sharded placement so several
    // per-group windows are in flight when the partition lands.
    for backend in ConsensusBackend::ALL {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        cfg.objects = CatalogSpec::parse("account:16").unwrap();
        cfg.objects.zipf_theta = 0.6;
        cfg.backend = backend;
        cfg.placement = LeaderPlacement::Hash;
        cfg.n_replicas = 5;
        cfg.update_pct = 25;
        cfg.total_ops = 8_000;
        cfg.window = 8;
        cfg.seed = 0x81D_8A1D;
        cfg.fault = FaultSchedule::parse("partition@40:1-2,heal@70").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed.iter().all(|&c| !c), "{b}: nobody crashed");
        assert_eq!(
            rep.groups_led.iter().sum::<u64>(),
            16,
            "{b}: every group has exactly one leader after the heal: {:?}",
            rep.groups_led
        );
        assert!(
            rep.converged() && rep.converged_per_object(),
            "{b}: diverged after heal: {:?}\n{}",
            rep.digests,
            rep.dumps.join("\n---\n")
        );
        assert!(rep.invariants_ok, "{b}: integrity broke (imposter window applied)");
        assert!(rep.metrics.smr_commits > 0, "{b}: strong path unexercised");
    }
}

#[test]
fn leader_crash_during_partition_with_window_converges_single_placement() {
    // The classic acceptance schedule with the pipeline open, on the
    // single-leader layout: partition two followers, crash the leader
    // mid-window, heal — the successor's takeover replay must cover every
    // uncommitted slot of the dead leader's window.
    for backend in ConsensusBackend::ALL {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        cfg.backend = backend;
        cfg.n_replicas = 5;
        cfg.update_pct = 25;
        cfg.total_ops = 10_000;
        cfg.window = 8;
        cfg.seed = 0x81D_8A2E;
        cfg.fault = FaultSchedule::parse("partition@40:1-2,crash@50:leader,heal@70").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed[0], "{b}: initial leader stays down");
        assert!(rep.metrics.elections >= 1, "{b}: re-election happened");
        assert!(rep.converged(), "{b}: diverged: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{b}: integrity broke");
        assert!(rep.metrics.smr_commits > 0, "{b}: strong path unexercised");
    }
}
