//! Activity-based power model (§5.5, Fig 27, appendix D.2).
//!
//! Power = static floor (FPGA fabric+HBM, or CPU package) + I/O subsystem
//! static (RNIC/PCIe/DRAM) + dynamic energy of executed transactions and
//! wire verbs amortized over the run's makespan. Calibrated so SafarDB
//! lands ≈35 W and Hamband ≈160 W with ≈2/3 of Hamband's draw on the CPU
//! (the paper's attribution).

use crate::config::PowerParams;
use crate::metrics::RunMetrics;

#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    pub static_w: f64,
    pub io_w: f64,
    pub dynamic_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.io_w + self.dynamic_w
    }

    /// Fraction attributable to the compute element (paper: ~2/3 for the
    /// CPU system).
    pub fn compute_fraction(&self) -> f64 {
        (self.static_w + self.dynamic_w) / self.total_w()
    }
}

pub fn estimate(params: &PowerParams, metrics: &RunMetrics) -> PowerReport {
    let elapsed_ns = metrics.makespan_ns.max(1) as f64;
    // nJ / ns == W.
    let dynamic_w = (params.op_nj * metrics.executions as f64
        + params.verb_nj * metrics.verbs as f64)
        / elapsed_ns;
    PowerReport { static_w: params.static_w, io_w: params.io_static_w, dynamic_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemParams;

    fn metrics_with(ops: u64, verbs: u64, ns: u64) -> RunMetrics {
        let mut m = RunMetrics::new(4);
        m.executions = ops;
        m.verbs = verbs;
        m.makespan_ns = ns;
        m
    }

    #[test]
    fn safardb_lands_near_35w() {
        let p = SystemParams::safardb().power;
        // ~2 ops/µs cluster-wide for 1 ms.
        let m = metrics_with(2_000, 6_000, 1_000_000);
        let r = estimate(&p, &m);
        assert!((32.0..40.0).contains(&r.total_w()), "total={}", r.total_w());
    }

    #[test]
    fn hamband_lands_near_160w_with_cpu_majority() {
        let p = SystemParams::hamband().power;
        let m = metrics_with(400, 1_200, 1_000_000);
        let r = estimate(&p, &m);
        assert!((140.0..175.0).contains(&r.total_w()), "total={}", r.total_w());
        assert!(r.compute_fraction() > 0.6, "cpu fraction {}", r.compute_fraction());
    }

    #[test]
    fn dynamic_power_scales_with_activity() {
        let p = SystemParams::safardb().power;
        let low = estimate(&p, &metrics_with(100, 100, 1_000_000));
        let high = estimate(&p, &metrics_with(100_000, 100_000, 1_000_000));
        assert!(high.dynamic_w > low.dynamic_w * 100.0);
    }
}
