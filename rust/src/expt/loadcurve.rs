//! Load curve — the open-loop saturation sweep: offered-load grid ×
//! arrival shape × consensus backend × batch × window × leadership
//! placement over a 16-instance Account catalog (per-(object, group)
//! strong ordering, so sharded placements, batching and pipelining all
//! matter). Each cell drives seeded per-node arrival streams (`arrival =
//! poisson:RATE` / `bursty:...`) through the admission queue and records
//! the latency-vs-offered-load knee the paper's fig. 6–11 family gestures
//! at: response percentiles rise gently until the service capacity knee,
//! then the queue fills, latency jumps an order of magnitude, and the shed
//! column takes off.
//!
//! Batching gets to show its real win here — coalescing under bursty
//! arrivals rather than under a fixed in-flight cap — so every rate runs
//! at `batch ∈ {1, 8}`; full sweeps additionally pipeline the strong plane
//! at `window ∈ {1, 8}` (the sliding window moves the knee by overlapping
//! consensus rounds instead of widening them). Seeds depend only on the
//! workload axes (arrival kind, rate, batch) — never on backend, placement
//! or window — so every pipeline depth of a cell faces the bit-identical
//! arrival stream. The CI smoke legs (`expt loadcurve --quick --threads 2
//! --backend ...` and `... --window 8`) run one backend per matrix job and
//! upload the CSV.

use crate::config::{
    ArrivalProcess, CatalogSpec, ConsensusBackend, LeaderPlacement, SimConfig, WorkloadKind,
};
use crate::expt::common::{backend_filter, f3, placement_filter, run_cells_tagged, window_filter};
use crate::rdt::RdtKind;
use crate::util::table::Table;

/// Offered load per node (ops/s of virtual time). The top of the grid sits
/// well past the service knee (~1–2M ops/s/node), the bottom well under it.
pub const RATE_SWEEP: &[u64] =
    &[50_000, 100_000, 200_000, 400_000, 800_000, 1_600_000, 3_200_000, 6_400_000];
pub const RATE_SWEEP_QUICK: &[u64] = &[100_000, 800_000, 6_400_000];

/// Bursty shape used on the non-poisson axis: 200 µs period, first half
/// 4× hotter than the second (mean rate preserved).
const BURST_PERIOD_NS: u64 = 200_000;
const BURST_AMP: u32 = 4;

fn arrival_kinds(rate: u64) -> [ArrivalProcess; 2] {
    [
        ArrivalProcess::Poisson { rate },
        ArrivalProcess::Bursty { rate, period_ns: BURST_PERIOD_NS, amp: BURST_AMP },
    ]
}

pub fn run(quick: bool) -> Vec<Table> {
    let backends: Vec<ConsensusBackend> = match backend_filter() {
        Some(b) => vec![b],
        None => ConsensusBackend::ALL.to_vec(),
    };
    let placements: Vec<LeaderPlacement> = match placement_filter() {
        Some(p) => vec![p],
        // Quick sweeps stay single-placement (CI opts into sharded legs
        // via --placement); full sweeps carry the comparison.
        None if quick => vec![LeaderPlacement::Single],
        None => vec![LeaderPlacement::Single, LeaderPlacement::Hash],
    };
    let windows: Vec<u32> = match window_filter() {
        Some(w) => vec![w],
        // Quick sweeps stay stop-and-wait (CI opts into pipelined legs
        // via --window); full sweeps carry the comparison.
        None if quick => vec![1],
        None => vec![1, 8],
    };
    let rates: &[u64] = if quick { RATE_SWEEP_QUICK } else { RATE_SWEEP };
    // `ops` is the cluster-wide arrival-stream budget (total offered ops),
    // not a completion target: saturated cells complete fewer (shed).
    let ops: u64 = if quick { 6_000 } else { 16_000 };

    let mut t = Table::new(
        "Load curve — offered load × arrival shape × backend × batch × window × placement \
         (account:16 catalog, 25% updates, open loop)",
        &[
            "arrival",
            "rate_per_node",
            "backend",
            "batch",
            "window",
            "placement",
            "nodes",
            "offered",
            "completed",
            "shed",
            "qdepth_max",
            "p50_us",
            "p95_us",
            "p99_us",
            "round_p99_us",
            "inflight_max",
            "rt_us",
            "tput_ops_us",
        ],
    );
    let mut jobs = Vec::new();
    for &placement in &placements {
        for &backend in &backends {
            for &window in &windows {
                for (ri, &rate) in rates.iter().enumerate() {
                    for (ki, arrival) in arrival_kinds(rate).into_iter().enumerate() {
                        for (qi, &batch) in [1u32, 8].iter().enumerate() {
                            let mut cfg =
                                SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                            cfg.objects = CatalogSpec::parse("account:16").expect("spec parses");
                            cfg.objects.zipf_theta = 0.6;
                            cfg.arrival = arrival;
                            cfg.backend = backend;
                            cfg.placement = placement;
                            cfg.batch_size = batch;
                            cfg.window = window;
                            cfg.n_replicas = 4;
                            cfg.update_pct = 25;
                            // Workload axes only: pipeline depths of a cell
                            // share the arrival stream bit-for-bit.
                            cfg.seed = 0x10AD_0000
                                + (ki as u64) * 0x10000
                                + (ri as u64) * 0x100
                                + qi as u64;
                            jobs.push((
                                (arrival, rate, backend, batch, window, placement),
                                (cfg, ops),
                            ));
                        }
                    }
                }
            }
        }
    }
    for ((arrival, rate, backend, batch, window, placement), cell, rep) in run_cells_tagged(jobs) {
        let m = &rep.metrics;
        t.row(vec![
            arrival.label().split(':').next().unwrap_or("?").to_string(),
            rate.to_string(),
            backend.name().into(),
            batch.to_string(),
            window.to_string(),
            placement.name().into(),
            "4".to_string(),
            m.offered.to_string(),
            m.total_completed().to_string(),
            m.shed.to_string(),
            m.queue_depth_max.to_string(),
            f3(m.response.p50() as f64 / 1_000.0),
            f3(m.response.p95() as f64 / 1_000.0),
            f3(m.response.p99() as f64 / 1_000.0),
            f3(m.smr_round.p99() as f64 / 1_000.0),
            m.inflight_max_overall().to_string(),
            f3(cell.rt_us),
            f3(cell.tput),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_has_knee_shape_and_conserves_offered_ops() {
        crate::expt::common::set_threads(2);
        let t = &run(true)[0];
        let backends = match backend_filter() {
            Some(_) => 1,
            None => ConsensusBackend::ALL.len(),
        };
        // rates × {poisson, bursty} × {batch 1, 8} × backends × 1 placement
        // × 1 window (quick pins the window axis like the placement axis).
        assert_eq!(t.rows().len(), RATE_SWEEP_QUICK.len() * 2 * 2 * backends);
        for row in t.rows() {
            let offered: u64 = row[7].parse().unwrap();
            let completed: u64 = row[8].parse().unwrap();
            let shed: u64 = row[9].parse().unwrap();
            // Fault-free: every offered arrival either completed or shed,
            // and the stream budget is exactly the per-node split of ops.
            assert_eq!(offered, 6_000, "full stream offered: {row:?}");
            assert_eq!(offered, completed + shed, "accounting identity: {row:?}");
            assert!(completed > 0, "saturated cells still serve: {row:?}");
            // Percentiles are order statistics of one histogram: p50 ≤
            // p95 ≤ p99 must hold in every cell.
            let p50: f64 = row[11].parse().unwrap();
            let p95: f64 = row[12].parse().unwrap();
            let p99: f64 = row[13].parse().unwrap();
            assert!(p50 <= p95 && p95 <= p99, "percentile ordering: {row:?}");
            // The pipeline never exceeds its configured depth.
            let window: u64 = row[4].parse().unwrap();
            let inflight: u64 = row[15].parse().unwrap();
            assert!(inflight <= window, "inflight {inflight} > window {window}: {row:?}");
        }
        // Knee shape per (backend, arrival, batch) series: the top of the
        // rate grid sits past saturation, so p99 must be far above the
        // bottom's and backpressure must be visible.
        for backend in match backend_filter() {
            Some(b) => vec![b],
            None => ConsensusBackend::ALL.to_vec(),
        } {
            for arrival in ["poisson", "bursty"] {
                for batch in ["1", "8"] {
                    let series: Vec<_> = t
                        .rows()
                        .iter()
                        .filter(|r| r[0] == arrival && r[2] == backend.name() && r[3] == batch)
                        .collect();
                    assert_eq!(series.len(), RATE_SWEEP_QUICK.len());
                    let p99_lo: f64 = series.first().unwrap()[13].parse().unwrap();
                    let p99_hi: f64 = series.last().unwrap()[13].parse().unwrap();
                    let shed_hi: u64 = series.last().unwrap()[9].parse().unwrap();
                    assert!(
                        p99_hi >= 5.0 * p99_lo,
                        "{} {arrival} batch={batch}: no knee: p99 {p99_lo} -> {p99_hi}",
                        backend.name()
                    );
                    assert!(
                        shed_hi > 0,
                        "{} {arrival} batch={batch}: overload never shed",
                        backend.name()
                    );
                }
            }
        }
    }
}
