//! Client plane: closed-loop client slots — quota accounting, workload
//! generation, per-origin sequence numbers, and the request-side read
//! costs (including the hybrid host cache, Figs 15–17).
//!
//! The pending-request maps for *forwarded* ops live with the strong path
//! (`engine::strong`), which owns their retry protocol; this plane only
//! tracks how many slots are in flight via `ReplicaCore::clients_in_flight`.

use crate::config::SimConfig;
use crate::engine::path::ReplicaCore;
use crate::mem::LruCache;
use crate::rdt::OpCall;
use crate::sim::Time;
use crate::workload::{Generator, WorkItem};

pub struct ClientPlane {
    gen: Generator,
    /// Remaining ops this replica's slots may issue (cluster-assigned;
    /// redistributed away from crashed replicas).
    pub quota: u64,
    op_seq: u64,
    /// Hybrid mode: host LLC model for host-resident keys.
    host_cache: Option<LruCache>,
}

impl ClientPlane {
    pub fn new(cfg: &SimConfig) -> Self {
        ClientPlane {
            gen: Generator::new(cfg),
            quota: 0,
            op_seq: 0,
            host_cache: cfg.hybrid.map(|h| LruCache::new(h.host_cache_keys)),
        }
    }

    /// Total keyspace the generator addresses (sizes the data plane).
    pub fn keyspace(&self) -> u64 {
        self.gen.keyspace()
    }

    /// Consume one quota slot and draw the next request, or `None` when the
    /// quota is spent (the slot retires). In catalog mode the generator
    /// selects the target object first (Zipfian over `objects =`), then a
    /// type-appropriate op; the returned op carries its `ObjectId`.
    pub fn next_op(&mut self, core: &mut ReplicaCore, now: Time) -> Option<WorkItem> {
        if self.quota == 0 {
            return None;
        }
        self.quota -= 1;
        self.op_seq += 1;
        // LWW timestamps compose (time, origin) so they are globally unique
        // and merge deterministically (Table A.1 "unique timestamps").
        let ts = ((now.max(1)) << 8) | core.id as u64;
        let mut item = self.gen.next(&mut core.rng, &core.plane, ts);
        item.op.origin = core.id;
        item.op.seq = self.op_seq;
        core.clients_in_flight += 1;
        Some(item)
    }

    /// Read cost of answering a query, after the paths' refresh fold:
    /// host-resident keys go through the LLC model and pay the PCIe
    /// response hop; on-fabric state is warm.
    pub fn query_read_cost(&mut self, core: &ReplicaCore, op: &OpCall, host_side: bool) -> u64 {
        if host_side {
            let hit = self.host_cache.as_mut().map(|c| c.access(op.b)).unwrap_or(false);
            core.sys.mem.host_keyed_read_ns(hit) + core.sys.mem.pcie_ns // response back over PCIe
        } else {
            core.warm_read_ns()
        }
    }

    /// Read cost of the permissibility precheck (§2.1) — same keyed read,
    /// no response egress.
    pub fn check_read_cost(&mut self, core: &ReplicaCore, op: &OpCall, host_side: bool) -> u64 {
        if host_side {
            let hit = self.host_cache.as_mut().map(|c| c.access(op.b)).unwrap_or(false);
            core.sys.mem.host_keyed_read_ns(hit)
        } else {
            core.warm_read_ns()
        }
    }
}
