//! PJRT executable registry: HLO text -> compile once -> execute many.
//!
//! Interchange is HLO *text* (see aot.py / /opt/xla-example/README.md: jax
//! >= 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
    /// Executions served (perf accounting).
    pub calls: u64,
}

impl Runtime {
    /// Load every artifact in `dir` (expects `manifest.txt` +
    /// `<name>.hlo.txt`, produced by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for sig in &manifest.entries {
            let path = dir.join(format!("{}.hlo.txt", sig.name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", sig.name))?;
            exes.insert(sig.name.clone(), exe);
        }
        Ok(Runtime { client, manifest, exes, dir, calls: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Execute `name` with the given input literals; returns the flattened
    /// output tuple.
    pub fn call(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let Some(sig) = self.manifest.get(name) else {
            bail!("unknown artifact {name}; have {:?}", self.names());
        };
        if inputs.len() != sig.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", sig.inputs.len(), inputs.len());
        }
        let exe = self.exes.get(name).expect("compiled artifact");
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        self.calls += 1;
        // aot.py lowers with return_tuple=True: flatten the tuple.
        let n_out = sig.outputs.len();
        let outs = result.to_tuple()?;
        if outs.len() != n_out {
            bail!("{name}: expected {n_out} outputs, got {}", outs.len());
        }
        Ok(outs)
    }

    /// f32 literal of the given 2-D shape (row-major).
    pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    pub fn lit_f32_1d(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    pub fn lit_i32_1d(data: &[i32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }
}
