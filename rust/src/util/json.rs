//! Minimal JSON value, writer, and parser (no serde offline). The writer
//! persists experiment results under results/ for EXPERIMENTS.md; the
//! parser reads them back for the `bench-compare` perf ratchet.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    /// Field lookup on an object; `None` on missing key or non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Parse a JSON document (recursive descent, strict enough for our
    /// own writer's output plus ordinary hand-edited files). Returns an
    /// error message with a byte offset on malformed input.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let val = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(val)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(b, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|()| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                m.insert(key, parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| format!("bad \\u escape at byte {pos}", pos = *pos))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape at byte {pos}", pos = *pos))?;
                        // Surrogate pairs are out of scope for our own files.
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}", pos = *pos)),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one whole UTF-8 scalar from the source text.
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| format!("invalid utf-8 at byte {pos}", pos = *pos))?;
                let c = rest.chars().next().unwrap();
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.set("name", "fig9".into());
        o.set("ratio", 7.0.into());
        o.set("series", Json::Arr(vec![1.0.into(), 2.5.into()]));
        assert_eq!(o.render(), r#"{"name":"fig9","ratio":7,"series":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(4.25).render(), "4.25");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let mut o = Json::obj();
        o.set("name", "bench".into());
        o.set("ok", Json::Bool(true));
        o.set("nothing", Json::Null);
        o.set("rate", 1234.5.into());
        o.set("cells", Json::Arr(vec![1.0.into(), "x\"y".into()]));
        let text = o.render();
        assert_eq!(Json::parse(&text).unwrap(), o);
    }

    #[test]
    fn parse_accepts_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , -2.5e1 ] ,\n \"b\" : \"x\\n\\u0041\" } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-25.0));
        assert_eq!(j.get("b").unwrap().as_str(), Some("x\nA"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} tail").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn accessors_are_type_checked() {
        let j = Json::parse(r#"{"s":"v","n":3,"b":false}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("v"));
        assert_eq!(j.get("n").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("missing"), None);
        assert_eq!(j.get("s").unwrap().as_f64(), None);
    }
}
