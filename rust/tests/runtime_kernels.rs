//! Integration: the kernel runtime vs the Rust scalar engine — batch
//! semantics == scalar semantics exactly. Runs against the AOT manifest
//! when `artifacts/` exists and against the builtin signatures otherwise
//! (the reference executor needs no compiled artifacts).

use safardb::rdt::crdt::counter::{PnCounter, OP_DECREMENT, OP_INCREMENT};
use safardb::rdt::Rdt;
use safardb::rdt::{wrdt::account::Account, wrdt::account::OP_DEPOSIT, wrdt::account::OP_WITHDRAW, OpCall};
use safardb::runtime::{Accelerator, Runtime};
use safardb::util::rng::Rng;

fn accel() -> Accelerator {
    Accelerator::new(Runtime::load("artifacts").expect("runtime load"))
}

#[test]
fn pn_merge_kernel_matches_scalar_counter() {
    let mut acc = accel();
    let mut rng = Rng::new(7);
    // Drive a scalar PN-Counter with random ops from 8 origins.
    let mut c = PnCounter::default();
    let (mut p_rows, mut m_rows) = (vec![vec![0f32]; 8], vec![vec![0f32]; 8]);
    for _ in 0..500 {
        let origin = rng.gen_range(8) as usize;
        let amount = 1 + rng.gen_range(9);
        let opcode = if rng.gen_bool(0.5) { OP_INCREMENT } else { OP_DECREMENT };
        let mut op = OpCall::new(opcode, amount, 0, 0.0);
        op.origin = origin;
        c.apply(&op);
        if opcode == OP_INCREMENT {
            p_rows[origin][0] += amount as f32;
        } else {
            m_rows[origin][0] += amount as f32;
        }
    }
    let merged = acc.pn_counter_merge(&p_rows, &m_rows).unwrap();
    assert_eq!(merged[0] as i64, c.value(), "kernel fold == scalar CRDT");
}

#[test]
fn account_guard_kernel_matches_scalar_account() {
    let mut acc = accel();
    let mut rng = Rng::new(8);
    let mut scalar = Account::default(); // balance 1000
    let mut deltas = Vec::new();
    let mut scalar_accepts = Vec::new();
    for _ in 0..200 {
        let op = if rng.gen_bool(0.5) {
            OpCall::new(OP_DEPOSIT, 0, 0, rng.gen_f64_range(1.0, 30.0))
        } else {
            OpCall::new(OP_WITHDRAW, 0, 0, rng.gen_f64_range(1.0, 60.0))
        };
        let d = if op.opcode == OP_DEPOSIT { op.x } else { -op.x };
        deltas.push(d as f32);
        scalar_accepts.push(scalar.apply(&op));
    }
    let (mask, balance) = acc.account_guard(1000.0, &deltas).unwrap();
    assert_eq!(mask, scalar_accepts, "kernel accept mask == scalar permissibility");
    assert!((balance as f64 - scalar.balance()).abs() < 0.05, "{balance} vs {}", scalar.balance());
    assert!(balance >= 0.0, "integrity invariant at the kernel level");
}

#[test]
fn lww_merge_kernel_picks_latest_writer() {
    let mut acc = accel();
    let vals = vec![vec![1.0f32, 10.0], vec![2.0, 20.0], vec![3.0, 30.0]];
    let ts = vec![vec![5i32, 9], vec![9, 5], vec![1, 1]];
    let (v, t) = acc.lww_merge(&vals, &ts).unwrap();
    assert_eq!(v, vec![2.0, 10.0]);
    assert_eq!(t, vec![9, 9]);
}

#[test]
fn set_kernels_match_bit_semantics() {
    let mut acc = accel();
    let adds = vec![vec![0b0111i32], vec![0b1000]];
    let removes = vec![vec![0b0001i32], vec![0b0000]];
    let g = acc.gset_merge(&adds).unwrap();
    assert_eq!(g[0], 0b1111);
    let p = acc.two_p_set_merge(&adds, &removes).unwrap();
    assert_eq!(p[0], 0b1110, "tombstoned bit stays out (2P rule)");
}

#[test]
fn kv_burst_matches_scalar_scatter_add() {
    let mut acc = accel();
    let mut rng = Rng::new(9);
    let mut state = vec![0f32; 600];
    let mut shadow = state.clone();
    for _ in 0..4 {
        let keys: Vec<i32> = (0..256).map(|_| rng.gen_range(600) as i32).collect();
        let deltas: Vec<f32> = (0..256).map(|_| rng.gen_f64_range(-10.0, 10.0) as f32).collect();
        state = acc.kv_burst_apply(&state, &keys, &deltas).unwrap();
        for (k, d) in keys.iter().zip(&deltas) {
            shadow[*k as usize] += d;
        }
    }
    for (i, (a, b)) in state.iter().zip(&shadow).enumerate() {
        assert!((a - b).abs() < 1e-3, "key {i}: {a} vs {b}");
    }
}

#[test]
fn smallbank_fused_kernel_guards_and_applies() {
    let mut acc = accel();
    let state = vec![10f32; 32];
    let keys: Vec<i32> = (0..8).collect();
    let deltas = vec![5f32; 8];
    let guard = vec![-40f32, -40.0, -40.0, 10.0, -20.0, -5.0, 0.0, -1.0];
    let (new_state, accepts, bal) = acc.smallbank_burst(&state, &keys, &deltas, 100.0, &guard).unwrap();
    // Scalar oracle for the guard scan.
    let mut b = 100f32;
    let mut expect = Vec::new();
    for d in &guard {
        let ok = *d >= 0.0 || b + d >= 0.0;
        if ok {
            b += d;
        }
        expect.push(ok);
    }
    assert_eq!(accepts, expect);
    assert!((bal - b).abs() < 1e-4);
    for (i, ok) in expect.iter().enumerate() {
        let want = if *ok { 15.0 } else { 10.0 };
        assert_eq!(new_state[i], want, "slot {i}");
    }
}

#[test]
fn oversized_inputs_rejected() {
    let mut acc = accel();
    let too_many = vec![0.0f32; 4096];
    assert!(acc.account_guard(1.0, &too_many).is_err());
    assert!(acc.kv_burst_apply(&too_many, &[0], &[0.0]).is_err());
}
