//! The replica's data plane: an ObjectId-addressed **catalog** of
//! heterogeneous RDT instances — micro-benchmark CRDTs/WRDTs and keyed KV
//! tenants (YCSB registers / SmallBank accounts) side by side — behind a
//! single category-routing interface: the paper's "single
//! replication/consistency interface across FPGA- and host-resident data"
//! (§1, contribution 3) hosting a catalog of data types with "direct
//! invocation of FPGA-resident operators".
//!
//! [`ObjectPlane`] is one catalog entry (the pre-catalog `DataPlane`);
//! [`Catalog`] is the dense `ObjectId -> ObjectPlane` table every replica
//! owns, which also flattens each object's local synchronization groups
//! into the cluster-global group index space the strong planes key their
//! round pipelines and replication logs by. A default configuration builds
//! a catalog of one and is bit-identical to the pre-catalog engine.

use crate::config::{ObjectKind, SimConfig, WorkloadKind};
use crate::rdt::{mix64, mix_f64, Category, ObjectId, OpCall, QueryValue, Rdt, RdtKind};

/// Keyspace of a KV tenant inside a multi-object catalog (the single-store
/// YCSB/SmallBank configurations keep their paper-scaled keyspaces; catalog
/// tenants are deliberately small so 64-tenant sweeps stay cheap).
pub const TENANT_KEYS: u64 = 4096;

/// KV opcodes (OpCall.b carries the key).
pub const KV_READ: u8 = 0xFE; // like query() but keyed
pub const KV_WRITE: u8 = 0; // YCSB update / SmallBank deposit  (reducible)
pub const KV_WITHDRAW: u8 = 1; // SmallBank debit (conflicting, overdraft guard)

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvKind {
    /// YCSB: last-writer-wins registers; updates are reducible.
    Ycsb,
    /// SmallBank: accounts with a non-negative-balance invariant; debits
    /// are conflicting (the Fig 11 "drastic drop at 5% updates" is the SMR
    /// engagement this category triggers).
    SmallBank,
}

#[derive(Clone, Debug)]
pub struct KvState {
    pub kind: KvKind,
    values: Vec<f64>,
    versions: Vec<u64>, // LWW timestamps for YCSB convergence
}

impl KvState {
    pub fn new(kind: KvKind, keys: u64) -> Self {
        let init = match kind {
            KvKind::Ycsb => 0.0,
            KvKind::SmallBank => 100.0, // seeded account balances
        };
        KvState {
            kind,
            values: vec![init; keys as usize],
            versions: vec![0; keys as usize],
        }
    }

    pub fn keys(&self) -> u64 {
        self.values.len() as u64
    }

    pub fn value(&self, key: u64) -> f64 {
        self.values[key as usize]
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        let k = op.b as usize;
        match (self.kind, op.opcode) {
            (KvKind::Ycsb, KV_WRITE) => {
                // LWW merge on (timestamp, origin): replicas converge
                // regardless of delivery order.
                let ts = op.a;
                if ts > self.versions[k] {
                    self.versions[k] = ts;
                    self.values[k] = op.x;
                    true
                } else {
                    false
                }
            }
            (KvKind::SmallBank, KV_WRITE) => {
                self.values[k] += op.x; // deposit: commutative add
                true
            }
            (KvKind::SmallBank, KV_WITHDRAW) => {
                if self.values[k] - op.x >= -1e-9 {
                    self.values[k] -= op.x;
                    true
                } else {
                    false
                }
            }
            _ => false,
        }
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match (self.kind, op.opcode) {
            (KvKind::SmallBank, KV_WITHDRAW) => {
                self.values[op.b as usize] - op.x >= -1e-9
            }
            _ => true,
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match (self.kind, op.opcode) {
            (KvKind::SmallBank, KV_WITHDRAW) => {
                self.values[op.b as usize] -= op.x; // leader-accepted debit
                true
            }
            _ => self.apply(op),
        }
    }

    /// Columnar batch apply (§Perf): the Rust port of
    /// `python/compile/kernels/batch_apply.py` — fold a whole op run into
    /// keyed state with the `(kind, opcode)` dispatch hoisted out of the
    /// per-op loop. The fold order is *exactly* the sequential order (f64
    /// addition is order-sensitive), so results are bit-identical to
    /// op-at-a-time `apply`; duplicate keys accumulate just like the
    /// kernel's one-hot scatter-add.
    fn apply_run(&mut self, ops: &[OpCall]) -> u64 {
        let mut ok = 0u64;
        match self.kind {
            KvKind::Ycsb => {
                for op in ops {
                    if op.opcode != KV_WRITE {
                        continue;
                    }
                    let k = op.b as usize;
                    if op.a > self.versions[k] {
                        self.versions[k] = op.a;
                        self.values[k] = op.x;
                        ok += 1;
                    }
                }
            }
            KvKind::SmallBank => {
                for op in ops {
                    let k = op.b as usize;
                    match op.opcode {
                        KV_WRITE => {
                            self.values[k] += op.x;
                            ok += 1;
                        }
                        KV_WITHDRAW => {
                            if self.values[k] - op.x >= -1e-9 {
                                self.values[k] -= op.x;
                                ok += 1;
                            }
                        }
                        _ => {}
                    }
                }
            }
        }
        ok
    }

    fn digest(&self) -> u64 {
        let mut acc = 0u64;
        for (k, (&v, &ver)) in self.values.iter().zip(&self.versions).enumerate() {
            // Round to cents: deposit folding order differs across replicas.
            let vq = (v * 100.0).round() / 100.0;
            if vq != 0.0 || ver != 0 {
                acc ^= mix64(k as u64 ^ (ver << 32)).wrapping_mul(mix_f64(vq) | 1);
            }
        }
        acc
    }

    fn invariant_ok(&self) -> bool {
        match self.kind {
            KvKind::Ycsb => true,
            KvKind::SmallBank => self.values.iter().all(|&v| v >= -1e-6),
        }
    }
}

/// One catalog object: a micro-benchmark RDT instance or a keyed KV tenant.
pub enum ObjectPlane {
    Micro(Box<dyn Rdt>),
    Kv(KvState),
}

impl ObjectPlane {
    pub fn for_workload(workload: WorkloadKind, keys: u64) -> Self {
        match workload {
            WorkloadKind::Micro(kind) => ObjectPlane::Micro(kind.instantiate()),
            WorkloadKind::Ycsb => ObjectPlane::Kv(KvState::new(KvKind::Ycsb, keys)),
            WorkloadKind::SmallBank => ObjectPlane::Kv(KvState::new(KvKind::SmallBank, keys)),
        }
    }

    pub fn for_kind(kind: ObjectKind) -> Self {
        match kind {
            ObjectKind::Rdt(k) => ObjectPlane::Micro(k.instantiate()),
            ObjectKind::Ycsb => ObjectPlane::Kv(KvState::new(KvKind::Ycsb, TENANT_KEYS)),
            ObjectKind::SmallBank => {
                ObjectPlane::Kv(KvState::new(KvKind::SmallBank, TENANT_KEYS))
            }
        }
    }

    pub fn category(&self, opcode: u8) -> Category {
        match self {
            ObjectPlane::Micro(r) => r.category(opcode),
            ObjectPlane::Kv(kv) => match (kv.kind, opcode) {
                (KvKind::SmallBank, KV_WITHDRAW) => Category::Conflicting,
                _ => Category::Reducible,
            },
        }
    }

    pub fn sync_group(&self, opcode: u8) -> u8 {
        match self {
            ObjectPlane::Micro(r) => r.sync_group(opcode),
            ObjectPlane::Kv(_) => 0,
        }
    }

    pub fn sync_groups(&self) -> u8 {
        match self {
            ObjectPlane::Micro(r) => r.sync_groups(),
            ObjectPlane::Kv(kv) => match kv.kind {
                KvKind::Ycsb => 0,
                KvKind::SmallBank => 1,
            },
        }
    }

    pub fn permissible(&self, op: &OpCall) -> bool {
        match self {
            ObjectPlane::Micro(r) => r.permissible(op),
            ObjectPlane::Kv(kv) => kv.permissible(op),
        }
    }

    pub fn apply(&mut self, op: &OpCall) -> bool {
        match self {
            ObjectPlane::Micro(r) => r.apply(op),
            ObjectPlane::Kv(kv) => kv.apply(op),
        }
    }

    /// Unconditional application of a leader-committed conflicting op
    /// (see `Rdt::apply_forced`).
    pub fn apply_forced(&mut self, op: &OpCall) -> bool {
        match self {
            ObjectPlane::Micro(r) => r.apply_forced(op),
            ObjectPlane::Kv(kv) => kv.apply_forced(op),
        }
    }

    /// Batch apply of an op run addressed to this object, with the
    /// `Micro`/`Kv` dispatch (and for KV tenants the kind/opcode match)
    /// resolved once per run instead of once per op. Returns the number of
    /// ops that applied (same count the per-op path would report).
    pub fn apply_run(&mut self, ops: &[OpCall]) -> u64 {
        match self {
            ObjectPlane::Micro(r) => {
                let mut ok = 0u64;
                for op in ops {
                    if r.apply(op) {
                        ok += 1;
                    }
                }
                ok
            }
            ObjectPlane::Kv(kv) => kv.apply_run(ops),
        }
    }

    pub fn query(&self, key: u64) -> QueryValue {
        match self {
            ObjectPlane::Micro(r) => r.query(),
            ObjectPlane::Kv(kv) => QueryValue::Float(kv.value(key)),
        }
    }

    pub fn has_query(&self) -> bool {
        match self {
            ObjectPlane::Micro(r) => r.has_query(),
            ObjectPlane::Kv(_) => true,
        }
    }

    pub fn state_digest(&self) -> u64 {
        match self {
            ObjectPlane::Micro(r) => r.state_digest(),
            ObjectPlane::Kv(kv) => kv.digest(),
        }
    }

    pub fn invariant_ok(&self) -> bool {
        match self {
            ObjectPlane::Micro(r) => r.invariant_ok(),
            ObjectPlane::Kv(kv) => kv.invariant_ok(),
        }
    }

    /// Type-correct summarization rule for this object's reducible ops
    /// (see `engine::relaxed::summarize`).
    pub fn summarize_rule(&self) -> crate::engine::relaxed::SummarizeRule {
        use crate::engine::relaxed::SummarizeRule as R;
        match self {
            ObjectPlane::Micro(r) => match r.kind() {
                RdtKind::GCounter | RdtKind::PnCounter | RdtKind::Account => R::SumDelta,
                RdtKind::LwwRegister => R::LastWrite,
                _ => R::ShipAll,
            },
            ObjectPlane::Kv(kv) => match kv.kind {
                KvKind::Ycsb => R::LastWrite,
                KvKind::SmallBank => R::SumDelta,
            },
        }
    }

    /// Deep-copy for recovery snapshot transfer.
    pub fn snapshot(&self) -> ObjectPlane {
        match self {
            ObjectPlane::Micro(r) => ObjectPlane::Micro(r.clone_box()),
            ObjectPlane::Kv(kv) => ObjectPlane::Kv(kv.clone()),
        }
    }

    pub fn debug_dump(&self) -> String {
        match self {
            ObjectPlane::Micro(r) => r.debug_dump(),
            ObjectPlane::Kv(_) => String::new(),
        }
    }

    pub fn micro_kind(&self) -> Option<RdtKind> {
        match self {
            ObjectPlane::Micro(r) => Some(r.kind()),
            ObjectPlane::Kv(_) => None,
        }
    }
}

/// The replica's data plane: a dense `ObjectId -> ObjectPlane` table plus
/// the `(object, local sync group) -> global group` flattening the strong
/// planes key their pipelines by, and per-object applied/rejected op
/// counters for the scale-out telemetry.
pub struct Catalog {
    objects: Vec<ObjectPlane>,
    /// Global group index of each object's local group 0 (cumulative sum
    /// of preceding objects' group counts).
    group_base: Vec<u8>,
    total_groups: u8,
    applied: Vec<u64>,
    rejected: Vec<u64>,
}

impl Catalog {
    /// Build the catalog a configuration describes: the explicit
    /// `objects =` spec, or the implicit catalog-of-one derived from
    /// `workload` (with `keyspace` sizing a single keyed store).
    pub fn for_config(cfg: &SimConfig, keyspace: u64) -> Self {
        let objects: Vec<ObjectPlane> = if cfg.objects.is_default() {
            vec![ObjectPlane::for_workload(cfg.workload, keyspace)]
        } else {
            cfg.objects
                .expanded_kinds()
                .into_iter()
                .map(ObjectPlane::for_kind)
                .collect()
        };
        Self::from_objects(objects)
    }

    fn from_objects(objects: Vec<ObjectPlane>) -> Self {
        let mut group_base = Vec::with_capacity(objects.len());
        let mut next = 0u32;
        for o in &objects {
            group_base.push(next as u8);
            next += o.sync_groups() as u32;
        }
        assert!(next <= u8::MAX as u32, "global sync groups exceed the wire format");
        let n = objects.len();
        Catalog {
            objects,
            group_base,
            total_groups: next as u8,
            applied: vec![0; n],
            rejected: vec![0; n],
        }
    }

    pub fn n_objects(&self) -> usize {
        self.objects.len()
    }

    pub fn object(&self, obj: ObjectId) -> &ObjectPlane {
        &self.objects[obj as usize]
    }

    /// Global synchronization-group count — the strong planes size their
    /// round pipelines and replication logs by this.
    pub fn total_groups(&self) -> u8 {
        self.total_groups
    }

    pub fn category(&self, obj: ObjectId, opcode: u8) -> Category {
        self.objects[obj as usize].category(opcode)
    }

    /// Flatten an op's `(object, local sync group)` into the global group
    /// index (Mu keeps one round pipeline + replication log per *global*
    /// group).
    pub fn global_group(&self, op: &OpCall) -> u8 {
        let o = op.obj as usize;
        self.group_base[o] + self.objects[o].sync_group(op.opcode)
    }

    pub fn permissible(&self, op: &OpCall) -> bool {
        self.objects[op.obj as usize].permissible(op)
    }

    pub fn apply(&mut self, op: &OpCall) -> bool {
        self.applied[op.obj as usize] += 1;
        self.objects[op.obj as usize].apply(op)
    }

    /// Columnar batch apply (§Perf, the `batch_apply.py` port): fold a
    /// summarized op vector into the catalog one *run* at a time, where a
    /// run is a maximal stretch of consecutive ops addressing the same
    /// object. Each run pays object lookup, virtual dispatch, and the
    /// applied-counter bump once instead of per op; the per-op fold order
    /// is untouched, so state and digests are bit-identical to calling
    /// [`Catalog::apply`] in a loop. Returns the number of ops that
    /// applied.
    pub fn apply_batch(&mut self, ops: &[OpCall]) -> u64 {
        let mut ok = 0u64;
        let mut i = 0;
        while i < ops.len() {
            let obj = ops[i].obj as usize;
            let mut j = i + 1;
            while j < ops.len() && ops[j].obj as usize == obj {
                j += 1;
            }
            self.applied[obj] += (j - i) as u64;
            ok += self.objects[obj].apply_run(&ops[i..j]);
            i = j;
        }
        ok
    }

    /// Unconditional apply of a leader-committed conflicting op.
    pub fn apply_forced(&mut self, op: &OpCall) -> bool {
        self.applied[op.obj as usize] += 1;
        self.objects[op.obj as usize].apply_forced(op)
    }

    pub fn query(&self, obj: ObjectId, key: u64) -> QueryValue {
        self.objects[obj as usize].query(key)
    }

    pub fn has_query(&self, obj: ObjectId) -> bool {
        self.objects[obj as usize].has_query()
    }

    /// Type-correct summarization rule for one object's reducible ops.
    pub fn summarize_rule(&self, obj: ObjectId) -> crate::engine::relaxed::SummarizeRule {
        self.objects[obj as usize].summarize_rule()
    }

    /// Whole-catalog digest. A catalog of one reports its object's digest
    /// unchanged (the pre-catalog value); larger catalogs combine
    /// per-object digests order-insensitively across objects.
    pub fn state_digest(&self) -> u64 {
        if self.objects.len() == 1 {
            return self.objects[0].state_digest();
        }
        self.objects
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, o)| {
                acc ^ mix64(i as u64).wrapping_mul(o.state_digest() | 1)
            })
    }

    /// Per-object digests (convergence must hold object by object).
    pub fn object_digests(&self) -> Vec<u64> {
        self.objects.iter().map(|o| o.state_digest()).collect()
    }

    pub fn invariant_ok(&self) -> bool {
        self.objects.iter().all(|o| o.invariant_ok())
    }

    /// Per-object applied-op counters (local + remote + forced applies).
    pub fn applied_counts(&self) -> &[u64] {
        &self.applied
    }

    /// Per-object permissibility-rejection counters.
    pub fn rejected_counts(&self) -> &[u64] {
        &self.rejected
    }

    /// Record a permissibility rejection against the op's object.
    pub fn note_rejected(&mut self, op: &OpCall) {
        self.rejected[op.obj as usize] += 1;
    }

    /// Transplant op counters across a snapshot install: the recovering
    /// node keeps *its own* telemetry, not the donor's.
    pub fn op_counts(&self) -> (Vec<u64>, Vec<u64>) {
        (self.applied.clone(), self.rejected.clone())
    }

    pub fn set_op_counts(&mut self, (applied, rejected): (Vec<u64>, Vec<u64>)) {
        debug_assert_eq!(applied.len(), self.objects.len());
        self.applied = applied;
        self.rejected = rejected;
    }

    /// Deep-copy for recovery snapshot transfer (op counters ride along but
    /// are replaced by the installer's own — see `Replica::install_snapshot`).
    pub fn snapshot(&self) -> Catalog {
        Catalog {
            objects: self.objects.iter().map(|o| o.snapshot()).collect(),
            group_base: self.group_base.clone(),
            total_groups: self.total_groups,
            applied: self.applied.clone(),
            rejected: self.rejected.clone(),
        }
    }

    pub fn debug_dump(&self) -> String {
        if self.objects.len() == 1 {
            return self.objects[0].debug_dump();
        }
        self.objects
            .iter()
            .enumerate()
            .map(|(i, o)| format!("[obj {i}] {}", o.debug_dump()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ycsb_lww_converges_out_of_order() {
        let mut a = KvState::new(KvKind::Ycsb, 8);
        let mut b = KvState::new(KvKind::Ycsb, 8);
        let mut w1 = OpCall::new(KV_WRITE, 10, 3, 1.5);
        w1.origin = 0;
        let mut w2 = OpCall::new(KV_WRITE, 20, 3, 2.5);
        w2.origin = 1;
        a.apply(&w1);
        a.apply(&w2);
        b.apply(&w2);
        b.apply(&w1);
        assert_eq!(a.value(3), 2.5);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn smallbank_withdraw_guard() {
        let mut kv = KvState::new(KvKind::SmallBank, 4);
        let w = OpCall::new(KV_WITHDRAW, 0, 2, 150.0);
        assert!(!kv.permissible(&w), "balance 100 < 150");
        assert!(!kv.apply(&w));
        assert!(kv.invariant_ok());
        let d = OpCall::new(KV_WRITE, 0, 2, 75.0);
        kv.apply(&d);
        assert!(kv.apply(&w));
        assert!((kv.value(2) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn objectplane_category_routing() {
        let sb = ObjectPlane::for_workload(WorkloadKind::SmallBank, 16);
        assert_eq!(sb.category(KV_WITHDRAW), Category::Conflicting);
        assert_eq!(sb.category(KV_WRITE), Category::Reducible);
        assert_eq!(sb.sync_groups(), 1);
        let y = ObjectPlane::for_workload(WorkloadKind::Ycsb, 16);
        assert_eq!(y.category(KV_WRITE), Category::Reducible);
        assert_eq!(y.sync_groups(), 0);
    }

    #[test]
    fn micro_plane_delegates() {
        let mut p = ObjectPlane::for_workload(WorkloadKind::Micro(RdtKind::PnCounter), 0);
        let op = OpCall::new(0, 5, 0, 0.0);
        assert!(p.permissible(&op));
        p.apply(&op);
        assert_eq!(p.query(0), QueryValue::Int(5));
        assert!(p.invariant_ok());
    }

    #[test]
    fn catalog_flattens_groups_and_routes_by_object() {
        use crate::config::CatalogSpec;
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        cfg.objects = CatalogSpec::parse("counter:2,account:2,auction:1").unwrap();
        let mut cat = Catalog::for_config(&cfg, 0);
        assert_eq!(cat.n_objects(), 5);
        // counters: no groups; accounts at global groups 0 and 1; the
        // auction's three local groups flatten to 2..=4.
        assert_eq!(cat.total_groups(), 5);
        use crate::rdt::wrdt::account::OP_WITHDRAW;
        let mut w = OpCall::new(OP_WITHDRAW, 0, 0, 10.0);
        w.obj = 2;
        assert_eq!(cat.category(w.obj, w.opcode), Category::Conflicting);
        assert_eq!(cat.global_group(&w), 0);
        w.obj = 3;
        assert_eq!(cat.global_group(&w), 1);

        // Applies land on the addressed object only, and are counted.
        let mut inc = OpCall::new(0, 7, 0, 0.0);
        inc.obj = 1;
        assert!(cat.apply(&inc));
        assert_eq!(cat.query(1, 0), QueryValue::Int(7));
        assert_eq!(cat.query(0, 0), QueryValue::Int(0));
        assert_eq!(cat.applied_counts(), &[0u64, 1, 0, 0, 0][..]);
        let digests = cat.object_digests();
        assert_ne!(digests[0], digests[1], "per-object digests distinguish state");
        assert!(cat.invariant_ok());
    }

    #[test]
    fn apply_batch_matches_op_at_a_time() {
        use crate::config::CatalogSpec;
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        cfg.objects = CatalogSpec::parse("counter:2,ycsb:1,smallbank:1").unwrap();
        let mut batched = Catalog::for_config(&cfg, 0);
        let mut serial = Catalog::for_config(&cfg, 0);

        // A mixed vector with object runs, duplicate keys, LWW races, and
        // an overdraft rejection — every dispatch arm the kernel hoists.
        let mut ops: Vec<OpCall> = Vec::new();
        let mut rng = 0x5AFA_2DB6u64;
        for i in 0..200u64 {
            rng = rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let r = rng >> 33;
            let mut op = match r % 4 {
                0 | 1 => OpCall::new((r % 2) as u8, r % 50, 0, 0.0), // counters
                2 => OpCall::new(KV_WRITE, 100 - (i % 7), r % 16, (r % 9) as f64),
                _ => OpCall::new(
                    if r % 3 == 0 { KV_WITHDRAW } else { KV_WRITE },
                    0,
                    r % 16,
                    (r % 300) as f64,
                ),
            };
            op.obj = match r % 4 {
                0 => 0,
                1 => 1,
                2 => 2,
                _ => 3,
            };
            op.origin = (r % 3) as usize;
            // Repeat each op a few times so same-object runs form.
            for _ in 0..(1 + r % 3) {
                ops.push(op);
            }
        }

        let mut serial_ok = 0u64;
        for op in &ops {
            if serial.apply(op) {
                serial_ok += 1;
            }
        }
        let batched_ok = batched.apply_batch(&ops);

        assert_eq!(batched_ok, serial_ok);
        assert_eq!(batched.object_digests(), serial.object_digests());
        assert_eq!(batched.state_digest(), serial.state_digest());
        assert_eq!(batched.applied_counts(), serial.applied_counts());
    }

    #[test]
    fn catalog_of_one_digest_matches_object_digest() {
        let cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        let mut cat = Catalog::for_config(&cfg, 0);
        let op = OpCall::new(0, 3, 0, 0.0);
        cat.apply(&op);
        assert_eq!(cat.state_digest(), cat.object(0).state_digest());
        assert_eq!(cat.object_digests().len(), 1);
    }
}
