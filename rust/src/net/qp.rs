//! Queue-pair permission table (QPC, §2.2).
//!
//! Each follower keeps one open QP granting write permission to the
//! current leader; on suspected leader failure it closes that QP and opens
//! one for the new leader (§4.4 "Permission Switch"). Writes through a
//! closed QP fail with a NACK — the mechanism Mu leans on to fence a
//! deposed leader.

use crate::sim::NodeId;

#[derive(Debug)]
pub struct QpTable {
    n: usize,
    /// `open[dst][src]` — may `src` write into `dst`'s memory?
    open: Vec<Vec<bool>>,
    /// Sharded placement only: `group_rows[dst]` = the per-group leader
    /// view `dst` last fenced against (`row[g]` = the one node whose
    /// leader-writes for group `g` are admitted). `None` under the classic
    /// single-leader table, where the boolean row is the whole story. With
    /// rows present, a node leading *some* group is still fenced for
    /// groups it does not lead — the property that makes partition-
    /// minority imposters harmless under sharding.
    group_rows: Vec<Option<Vec<NodeId>>>,
}

impl QpTable {
    /// All-open mesh (relaxed-path traffic is always permitted; only the
    /// leader-write QPs get fenced).
    pub fn full_mesh(n: usize) -> Self {
        QpTable { n, open: vec![vec![true; n]; n], group_rows: vec![None; n] }
    }

    /// Paper-faithful boot state (§4.4): each replica grants leader-write
    /// permission to exactly one peer — the current leader. A node that
    /// wrongly elects itself (e.g. inside a partition minority) is fenced
    /// at every correct replica, which is what makes split-brain writes
    /// impossible; the table checks only `leader_qp` verbs, so relaxed
    /// traffic is unaffected.
    pub fn leader_fenced(n: usize, leader: NodeId) -> Self {
        let mut t = QpTable { n, open: vec![vec![false; n]; n], group_rows: vec![None; n] };
        for dst in 0..n {
            t.open(dst, leader);
            t.open(dst, dst); // self-writes are local, never fenced
        }
        t
    }

    pub fn is_open(&self, src: NodeId, dst: NodeId) -> bool {
        self.open[dst][src]
    }

    /// Group-aware permission check: under sharded placement a leader-QP
    /// write is admitted only when `src` is the leader `dst` fenced for
    /// that *specific* group (self-writes are local, never fenced). Falls
    /// back to the boolean row when no per-group row exists (single
    /// placement) or the payload carries no group tag (forwards, syncs —
    /// those are not one-sided leader writes).
    pub fn is_open_for(&self, src: NodeId, dst: NodeId, group: Option<u8>) -> bool {
        if let (Some(row), Some(g)) = (&self.group_rows[dst], group) {
            return src == dst || row.get(g as usize).is_some_and(|&l| l == src);
        }
        self.open[dst][src]
    }

    pub fn close(&mut self, dst: NodeId, src: NodeId) {
        self.open[dst][src] = false;
    }

    pub fn open(&mut self, dst: NodeId, src: NodeId) {
        self.open[dst][src] = true;
    }

    /// Permission switch at `dst`: fence `old_leader`, grant `new_leader`.
    pub fn switch_leader(&mut self, dst: NodeId, old_leader: NodeId, new_leader: NodeId) {
        if old_leader != dst {
            self.close(dst, old_leader);
        }
        self.open(dst, new_leader);
    }

    /// Sharded boot state: each replica grants leader-write permission to
    /// every per-group leader (`leaders[g]` = leader of global sync group
    /// `g`). Collapses to [`QpTable::leader_fenced`] when every group maps
    /// to the same node.
    pub fn leaders_fenced(n: usize, leaders: &[NodeId]) -> Self {
        let mut t = QpTable {
            n,
            open: vec![vec![false; n]; n],
            group_rows: vec![Some(leaders.to_vec()); n],
        };
        for dst in 0..n {
            for &l in leaders {
                t.open(dst, l);
            }
            t.open(dst, dst); // self-writes are local, never fenced
        }
        t
    }

    /// Sharded permission switch at `dst`: rebuild `dst`'s grant row so
    /// exactly the current per-group leaders (plus `dst` itself) may
    /// leader-write. One table rebuild per placement change, however many
    /// groups moved.
    pub fn refence(&mut self, dst: NodeId, leaders: &[NodeId]) {
        for src in 0..self.n {
            self.open[dst][src] = src == dst || leaders.contains(&src);
        }
        self.group_rows[dst] = Some(leaders.to_vec());
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_fully_open() {
        let t = QpTable::full_mesh(4);
        for s in 0..4 {
            for d in 0..4 {
                assert!(t.is_open(s, d));
            }
        }
    }

    #[test]
    fn close_blocks_one_direction_only() {
        let mut t = QpTable::full_mesh(3);
        t.close(1, 0); // node 0 may no longer write into node 1
        assert!(!t.is_open(0, 1));
        assert!(t.is_open(1, 0), "reverse direction unaffected");
        assert!(t.is_open(0, 2));
    }

    #[test]
    fn switch_leader_fences_old_grants_new() {
        let mut t = QpTable::full_mesh(4);
        t.switch_leader(2, 0, 1);
        assert!(!t.is_open(0, 2), "old leader fenced");
        assert!(t.is_open(1, 2), "new leader granted");
    }

    #[test]
    fn leaders_fenced_grants_every_group_leader() {
        // Groups 0..4 led by nodes 0, 2, 0, 2 — only 0 and 2 (and self) open.
        let t = QpTable::leaders_fenced(4, &[0, 2, 0, 2]);
        for dst in 0..4 {
            assert!(t.is_open(0, dst));
            assert!(t.is_open(2, dst));
            assert_eq!(t.is_open(1, dst), dst == 1, "non-leader 1 fenced at {dst}");
            assert_eq!(t.is_open(3, dst), dst == 3, "non-leader 3 fenced at {dst}");
        }
    }

    #[test]
    fn leaders_fenced_single_leader_matches_leader_fenced() {
        let a = QpTable::leaders_fenced(4, &[1, 1, 1]);
        let b = QpTable::leader_fenced(4, 1);
        for src in 0..4 {
            for dst in 0..4 {
                assert_eq!(a.is_open(src, dst), b.is_open(src, dst), "{src}->{dst}");
            }
        }
    }

    #[test]
    fn refence_rebuilds_one_row_only() {
        let mut t = QpTable::leaders_fenced(4, &[0, 0]);
        t.refence(2, &[0, 3]);
        // Row 2 now admits 0, 3, and self.
        assert!(t.is_open(0, 2));
        assert!(t.is_open(3, 2));
        assert!(t.is_open(2, 2));
        assert!(!t.is_open(1, 2));
        // Other rows untouched: 3 still fenced at dst 1.
        assert!(!t.is_open(3, 1));
        assert!(t.is_open(0, 1));
    }

    #[test]
    fn group_fence_admits_only_that_groups_leader() {
        // Groups 0..4 led by nodes 0, 2, 0, 2. Node 2 legitimately leads
        // groups 1 and 3 — but its leader-writes tagged for group 0 must
        // still bounce: per-group fencing distinguishes "a leader" from
        // "the leader of this group".
        let t = QpTable::leaders_fenced(4, &[0, 2, 0, 2]);
        for dst in 0..4 {
            assert!(t.is_open_for(2, dst, Some(1)), "rightful write at {dst}");
            assert_eq!(t.is_open_for(2, dst, Some(0)), dst == 2, "imposter write at {dst}");
            assert_eq!(t.is_open_for(1, dst, Some(2)), dst == 1, "non-leader fenced at {dst}");
        }
        // Untagged payloads (forwards, syncs) keep the boolean-row answer.
        assert!(t.is_open_for(2, 0, None));
        assert!(!t.is_open_for(1, 0, None));
    }

    #[test]
    fn group_fence_absent_under_single_placement() {
        // Single-leader tables carry no per-group rows: a group tag (Raft
        // shard 0 traffic exists even unsharded) falls back to the boolean
        // row, keeping the classic behavior bit-identical.
        let mut t = QpTable::leader_fenced(4, 0);
        assert!(t.is_open_for(0, 2, Some(0)));
        assert!(!t.is_open_for(1, 2, Some(0)));
        t.switch_leader(2, 0, 1);
        assert!(t.is_open_for(1, 2, Some(0)), "switch_leader governs untagged rows");
        assert!(!t.is_open_for(0, 2, Some(0)));
    }

    #[test]
    fn refence_updates_the_group_row() {
        let mut t = QpTable::leaders_fenced(4, &[0, 0]);
        t.refence(2, &[0, 3]);
        assert!(t.is_open_for(3, 2, Some(1)), "new group-1 leader admitted");
        assert!(!t.is_open_for(3, 2, Some(0)), "but not for group 0");
        assert!(!t.is_open_for(0, 2, Some(1)), "old leader out of group 1");
        // Other rows keep their boot view.
        assert!(!t.is_open_for(3, 1, Some(1)));
        assert!(t.is_open_for(0, 1, Some(1)));
    }

    #[test]
    fn leader_fenced_boot_grants_only_the_leader() {
        let t = QpTable::leader_fenced(4, 0);
        for dst in 0..4 {
            assert!(t.is_open(0, dst), "leader may write everywhere");
            for src in 1..4 {
                assert_eq!(t.is_open(src, dst), src == dst, "non-leaders fenced: {src}->{dst}");
            }
        }
    }
}
