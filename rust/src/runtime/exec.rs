//! Kernel executor: typed literals in, typed literals out, signature-checked
//! against the artifact manifest.
//!
//! The seed executed AOT-lowered HLO through PJRT bindings; neither the
//! `xla` crate nor `anyhow` exists in the offline crate set, so the runtime
//! now ships a **std-only reference executor**. Each exported kernel is
//! implemented natively with semantics identical to its Pallas source in
//! `python/compile/kernels` (f32 arithmetic, sequential guard scans,
//! argmax-first tie-breaks); the `runtime_kernels` integration tests pin
//! those semantics against the scalar RDT engine. `Runtime::load` still
//! reads `artifacts/manifest.txt` when present (produced by
//! `python -m compile.aot`) and type-checks every call against it; when the
//! artifacts are absent it falls back to the built-in export signatures, so
//! `safardb runtime-check` degrades gracefully instead of failing.

use std::path::{Path, PathBuf};

use super::artifacts::{DType, Manifest};
use super::error::{Error, Result};

/// A dense tensor value (row-major).
#[derive(Clone, Debug)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Literal {
    pub fn dtype(&self) -> DType {
        match self {
            Literal::F32 { .. } => DType::F32,
            Literal::I32 { .. } => DType::I32,
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Literal::F32 { dims, .. } => dims,
            Literal::I32 { dims, .. } => dims,
        }
    }

    pub fn elems(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Literal::F32 { data, .. } => Ok(data),
            Literal::I32 { .. } => Err(Error::msg("expected f32 literal, got i32")),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Literal::I32 { data, .. } => Ok(data),
            Literal::F32 { .. } => Err(Error::msg("expected i32 literal, got f32")),
        }
    }
}

pub struct Runtime {
    manifest: Manifest,
    dir: PathBuf,
    loaded_from_disk: bool,
    /// Executions served (perf accounting).
    pub calls: u64,
}

impl Runtime {
    /// Load the artifact manifest in `dir` when it exists; otherwise fall
    /// back to the built-in export signatures (the reference executor needs
    /// no compiled artifacts to run).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        if dir.join("manifest.txt").exists() {
            let manifest = Manifest::load(&dir)?;
            for builtin in &Manifest::builtin().entries {
                match manifest.get(&builtin.name) {
                    None => {
                        return Err(Error::msg(format!(
                            "manifest in {dir:?} is missing kernel '{}' (stale artifacts? re-run `make artifacts`)",
                            builtin.name
                        )));
                    }
                    Some(loaded)
                        if loaded.inputs != builtin.inputs
                            || loaded.outputs != builtin.outputs =>
                    {
                        return Err(Error::msg(format!(
                            "manifest in {dir:?} disagrees with the builtin export table for '{}' \
                             (old artifacts? re-run `make artifacts`; export shapes changed in \
                             python/compile/model.py? update Manifest::builtin in \
                             rust/src/runtime/artifacts.rs to match)",
                            builtin.name
                        )));
                    }
                    Some(_) => {}
                }
            }
            Ok(Runtime { manifest, dir, loaded_from_disk: true, calls: 0 })
        } else {
            Ok(Runtime { manifest: Manifest::builtin(), dir, loaded_from_disk: false, calls: 0 })
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether signatures were type-checked against on-disk AOT artifacts.
    pub fn loaded_from_disk(&self) -> bool {
        self.loaded_from_disk
    }

    pub fn platform(&self) -> String {
        if self.loaded_from_disk {
            format!("native-reference (manifest: {})", self.dir.display())
        } else {
            "native-reference (builtin signatures; AOT artifacts absent)".to_string()
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Execute `name` with the given input literals; returns the flattened
    /// output tuple, shape-checked on both sides.
    pub fn call(&mut self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let Some(sig) = self.manifest.get(name) else {
            return Err(Error::msg(format!("unknown artifact {name}; have {:?}", self.names())));
        };
        if inputs.len() != sig.inputs.len() {
            return Err(Error::msg(format!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (lit, want)) in inputs.iter().zip(&sig.inputs).enumerate() {
            if lit.dtype() != want.dtype || lit.dims() != want.shape.as_slice() {
                return Err(Error::msg(format!(
                    "{name}: input {i} is {:?}{:?}, signature wants {:?}{:?}",
                    lit.dtype(),
                    lit.dims(),
                    want.dtype,
                    want.shape
                )));
            }
            // Literal fields are public: guard against hand-built literals
            // whose buffer disagrees with their claimed dims (the executors
            // index by dims and would panic otherwise).
            if lit.elems() != want.elems() {
                return Err(Error::msg(format!(
                    "{name}: input {i} holds {} elements but claims shape {:?}",
                    lit.elems(),
                    lit.dims()
                )));
            }
        }
        let outs = dispatch(name, inputs)?;
        if outs.len() != sig.outputs.len() {
            return Err(Error::msg(format!(
                "{name}: executor produced {} outputs, signature wants {}",
                outs.len(),
                sig.outputs.len()
            )));
        }
        for (i, (lit, want)) in outs.iter().zip(&sig.outputs).enumerate() {
            if lit.dtype() != want.dtype || lit.dims() != want.shape.as_slice() {
                return Err(Error::msg(format!(
                    "{name}: output {i} is {:?}{:?}, signature wants {:?}{:?}",
                    lit.dtype(),
                    lit.dims(),
                    want.dtype,
                    want.shape
                )));
            }
        }
        self.calls += 1;
        Ok(outs)
    }

    /// f32 literal of the given 2-D shape (row-major).
    pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
        if data.len() != rows * cols {
            return Err(Error::msg(format!(
                "f32 literal: {} elements for shape {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Literal::F32 { data: data.to_vec(), dims: vec![rows, cols] })
    }

    pub fn lit_f32_1d(data: &[f32]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len()] }
    }

    pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<Literal> {
        if data.len() != rows * cols {
            return Err(Error::msg(format!(
                "i32 literal: {} elements for shape {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Literal::I32 { data: data.to_vec(), dims: vec![rows, cols] })
    }

    pub fn lit_i32_1d(data: &[i32]) -> Literal {
        Literal::I32 { data: data.to_vec(), dims: vec![data.len()] }
    }
}

/// (rows, cols) of a 2-D literal.
fn dims2(lit: &Literal) -> Result<(usize, usize)> {
    match lit.dims() {
        [r, c] => Ok((*r, *c)),
        other => Err(Error::msg(format!("expected rank-2 literal, got shape {other:?}"))),
    }
}

/// Sequential overdraft guard scan (mirrors kernels/permissibility.py):
/// deposits (d >= 0) always accepted; withdrawals accepted iff the running
/// balance stays non-negative. f32 arithmetic, batch order.
fn guard_scan(b0: f32, deltas: &[f32]) -> (Vec<i32>, f32) {
    let mut bal = b0;
    let mut mask = Vec::with_capacity(deltas.len());
    for &d in deltas {
        let ok = d >= 0.0 || bal + d >= 0.0;
        if ok {
            bal += d;
        }
        mask.push(ok as i32);
    }
    (mask, bal)
}

/// Scatter-add a burst into a state tile (mirrors kernels/batch_apply.py).
/// Out-of-range keys are dropped, matching XLA scatter's OOB behavior.
fn scatter_add(state: &[f32], keys: &[i32], deltas: &[f32]) -> Vec<f32> {
    let mut out = state.to_vec();
    for (&k, &d) in keys.iter().zip(deltas) {
        if let Some(slot) = out.get_mut(k as usize) {
            *slot += d;
        }
    }
    out
}

/// Execute one named kernel. Shapes were validated by the caller.
fn dispatch(name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
    match name {
        "pn_counter_merge" => {
            let (n, k) = dims2(&inputs[0])?;
            let p = inputs[0].f32s()?;
            let m = inputs[1].f32s()?;
            let mut out = vec![0f32; k];
            for (col, slot) in out.iter_mut().enumerate() {
                // Mirror pn_merge.py exactly: sum each G-Counter fully,
                // subtract once — interleaving (p - m) per row rounds
                // differently under f32 cancellation.
                let mut sum_p = 0f32;
                let mut sum_m = 0f32;
                for row in 0..n {
                    sum_p += p[row * k + col];
                    sum_m += m[row * k + col];
                }
                *slot = sum_p - sum_m;
            }
            Ok(vec![Literal::F32 { data: out, dims: vec![k] }])
        }
        "lww_register_merge" => {
            let (n, k) = dims2(&inputs[0])?;
            let vals = inputs[0].f32s()?;
            let ts = inputs[1].i32s()?;
            let mut out_v = vec![0f32; k];
            let mut out_t = vec![0i32; k];
            for col in 0..k {
                // argmax-first: on timestamp ties the lowest replica index
                // wins (same rule as the lww_merge kernel and rdt/crdt/lww).
                let mut best_row = 0usize;
                for row in 1..n {
                    if ts[row * k + col] > ts[best_row * k + col] {
                        best_row = row;
                    }
                }
                out_v[col] = vals[best_row * k + col];
                out_t[col] = ts[best_row * k + col];
            }
            Ok(vec![
                Literal::F32 { data: out_v, dims: vec![k] },
                Literal::I32 { data: out_t, dims: vec![k] },
            ])
        }
        "gset_merge" => {
            let (n, w) = dims2(&inputs[0])?;
            let maps = inputs[0].i32s()?;
            let mut out = vec![0i32; w];
            for (col, slot) in out.iter_mut().enumerate() {
                for row in 0..n {
                    *slot |= maps[row * w + col];
                }
            }
            Ok(vec![Literal::I32 { data: out, dims: vec![w] }])
        }
        "two_p_set_merge" => {
            let (n, w) = dims2(&inputs[0])?;
            let adds = inputs[0].i32s()?;
            let removes = inputs[1].i32s()?;
            let mut out = vec![0i32; w];
            for (col, slot) in out.iter_mut().enumerate() {
                let mut a = 0i32;
                let mut r = 0i32;
                for row in 0..n {
                    a |= adds[row * w + col];
                    r |= removes[row * w + col];
                }
                *slot = a & !r;
            }
            Ok(vec![Literal::I32 { data: out, dims: vec![w] }])
        }
        "account_guard" => {
            let b0 = inputs[0].f32s()?[0];
            let deltas = inputs[1].f32s()?;
            let (mask, bal) = guard_scan(b0, deltas);
            Ok(vec![
                Literal::I32 { data: mask, dims: vec![deltas.len()] },
                Literal::F32 { data: vec![bal], dims: vec![1] },
            ])
        }
        "kv_burst_apply" => {
            let state = inputs[0].f32s()?;
            let keys = inputs[1].i32s()?;
            let deltas = inputs[2].f32s()?;
            let out = scatter_add(state, keys, deltas);
            let dims = vec![out.len()];
            Ok(vec![Literal::F32 { data: out, dims }])
        }
        "smallbank_burst" => {
            let state = inputs[0].f32s()?;
            let keys = inputs[1].i32s()?;
            let deltas = inputs[2].f32s()?;
            let b0 = inputs[3].f32s()?[0];
            let guard_deltas = inputs[4].f32s()?;
            let (mask, bal) = guard_scan(b0, guard_deltas);
            // masked = deltas * accept (model.py smallbank_burst), then the
            // usual scatter-add.
            let masked: Vec<f32> = deltas
                .iter()
                .zip(&mask)
                .map(|(&d, &ok)| d * ok as f32)
                .collect();
            let out = scatter_add(state, keys, &masked);
            let k = out.len();
            let b = mask.len();
            Ok(vec![
                Literal::F32 { data: out, dims: vec![k] },
                Literal::I32 { data: mask, dims: vec![b] },
                Literal::F32 { data: vec![bal], dims: vec![1] },
            ])
        }
        other => Err(Error::msg(format!("no executor for kernel '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_falls_back_to_builtin_without_artifacts() {
        let rt = Runtime::load("definitely/not/a/dir").unwrap();
        assert!(!rt.loaded_from_disk());
        assert!(rt.platform().contains("absent"));
        assert_eq!(rt.names().len(), 7);
    }

    #[test]
    fn call_type_checks_inputs() {
        let mut rt = Runtime::load("nope").unwrap();
        // Wrong arity.
        assert!(rt.call("pn_counter_merge", &[]).is_err());
        // Wrong dtype.
        let zeros_i = vec![0i32; 8 * 1024];
        let zeros_f = vec![0f32; 8 * 1024];
        let bad = Runtime::lit_i32_2d(&zeros_i, 8, 1024).unwrap();
        let good = Runtime::lit_f32_2d(&zeros_f, 8, 1024).unwrap();
        assert!(rt.call("pn_counter_merge", &[bad, good.clone()]).is_err());
        // Unknown kernel.
        assert!(rt.call("nope", &[]).is_err());
        assert_eq!(rt.calls, 0, "failed calls are not counted");
        let good2 = Runtime::lit_f32_2d(&zeros_f, 8, 1024).unwrap();
        assert!(rt.call("pn_counter_merge", &[good, good2]).is_ok());
        assert_eq!(rt.calls, 1);
    }

    #[test]
    fn guard_scan_matches_paper_rule() {
        let (mask, bal) = guard_scan(100.0, &[-40.0, -40.0, -40.0, 10.0, -20.0]);
        assert_eq!(mask, vec![1, 1, 0, 1, 1]);
        assert!((bal - 10.0).abs() < 1e-6);
    }

    #[test]
    fn scatter_add_accumulates_duplicates_and_drops_oob() {
        let out = scatter_add(&[0.0, 0.0], &[1, 1, 9], &[2.0, 3.0, 7.0]);
        assert_eq!(out, vec![0.0, 5.0]);
    }
}
